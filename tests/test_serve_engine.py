"""Paged-KV serving engine: cache parity, flash-decode numerics, block
allocator properties, continuous-batching end-to-end, sampler, and the
no-recompile contract of ``greedy_generate``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attention.chunked import chunked_attention
from repro.kernels.flash_attention.decode import (flash_decode_paged,
                                                 paged_attention_reference)
from repro.models import model as M
from repro.models import params as P
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.paged_cache import BlockAllocator, PagedKVCache, blocks_for
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.step import greedy_generate, jitted_decode_step

from conftest import tiny


def _cfg(arch, **patch):
    cfg = tiny(get_config(arch))
    return dataclasses.replace(cfg, **patch) if patch else cfg


# --------------------------------------------------------------------------- #
# Paged vs dense decode parity
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch,patch", [
    ("qwen2-7b", dict(num_kv_heads=2)),          # GQA (+ qkv bias)
    ("mixtral-8x7b", dict(sliding_window=6)),    # SWA + MoE decoder
    ("opt-125m", {}),                            # learned positions
])
def test_paged_vs_dense_decode_logits(arch, patch):
    """Teacher-forcing the same prompt through decode_step (dense cache)
    and decode_step_paged (block-table cache) yields identical logits."""
    cfg = _cfg(arch, **patch)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    B, S, bs = 2, 11, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    dense = M.init_cache(cfg, B, 16, jnp.float32)
    kv = PagedKVCache(num_blocks=12, block_size=bs, max_slots=B,
                      max_blocks_per_seq=4)
    pages = M.init_paged_cache(cfg, 12, bs, jnp.float32)
    for s in range(B):
        kv.open_slot(s)

    for i in range(S):
        ld, dense = M.decode_step(params, cfg, dense, prompt[:, i:i + 1],
                                  jnp.int32(i))
        for s in range(B):
            assert kv.ensure_capacity(s)
        lp, pages = M.decode_step_paged(
            params, cfg, pages, prompt[:, i:i + 1],
            jnp.asarray(kv.device_tables()), jnp.asarray(kv.seq_lens()))
        for s in range(B):
            kv.commit_token(s)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} step {i}")


# --------------------------------------------------------------------------- #
# Flash-decode kernel numerics
# --------------------------------------------------------------------------- #

DECODE_CASES = [
    # H, K, D, bs, lens, window
    (4, 2, 64, 8, (17, 40), 0),          # GQA
    (4, 4, 32, 4, (1, 26), 0),           # MHA, fresh seq
    (8, 2, 64, 16, (33, 64), 20),        # GQA + sliding window
    (2, 1, 16, 4, (5, 12), 5),           # window < block
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_matches_chunked_reference(case):
    """Pallas flash-decode (interpret) over scattered pages == the chunked
    XLA flash kernel's last causal row over the equivalent dense KV."""
    H, K, D, bs, lens, window = case
    B = len(lens)
    nb = blocks_for(max(lens), bs)
    P_pool = B * nb + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pages = jax.random.normal(ks[1], (P_pool, bs, K, D))
    v_pages = jax.random.normal(ks[2], (P_pool, bs, K, D))
    # disjoint per-sequence block tables (page 0 = null)
    bt = (1 + np.arange(B * nb, dtype=np.int32)).reshape(B, nb)
    sl = jnp.asarray(lens, jnp.int32)

    out = flash_decode_paged(q, k_pages, v_pages, jnp.asarray(bt), sl,
                             window=window, pages_per_split=3,
                             interpret=True)
    ref = paged_attention_reference(q, k_pages, v_pages, jnp.asarray(bt),
                                    sl, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # cross-check against chunked.py on the gathered dense layout: the
    # decode output is the last causal row of full-sequence attention
    for b, L in enumerate(lens):
        kd = k_pages[bt[b]].reshape(-1, K, D)[None, :L]
        vd = v_pages[bt[b]].reshape(-1, K, D)[None, :L]
        qd = jnp.zeros((1, L, H, D)).at[:, L - 1].set(q[b])
        full = chunked_attention(qd, kd, vd, causal=True, window=window,
                                 chunk=8)
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(full[0, L - 1]),
                                   rtol=2e-5, atol=2e-5, err_msg=f"seq {b}")


# --------------------------------------------------------------------------- #
# Block allocator / paged-cache properties
# --------------------------------------------------------------------------- #

def _exercise_allocator(seed: int, num_blocks: int = 17, block_size: int = 4,
                        max_slots: int = 5, steps: int = 300):
    """Random alloc/append/free op machine; checks the paging invariants."""
    rng = np.random.RandomState(seed)
    kv = PagedKVCache(num_blocks=num_blocks, block_size=block_size,
                      max_slots=max_slots, max_blocks_per_seq=6)
    usable = kv.allocator.num_usable
    for _ in range(steps):
        op = rng.randint(3)
        free_slots = kv.free_slots()
        live = [i for i in range(max_slots) if i not in free_slots]
        if op == 0 and free_slots:
            kv.open_slot(free_slots[0])
        elif op == 1 and live:
            slot = live[rng.randint(len(live))]
            before = kv.allocator.num_free
            ok = kv.ensure_capacity(slot)
            if ok:
                kv.commit_token(slot)
                t = kv.table(slot)
                assert t.num_tokens <= t.allocated_tokens(block_size)
            else:
                # OOM must coincide with exhaustion (pool or table limit)
                t = kv.table(slot)
                assert (before == 0 or len(t.blocks) >= 6)
        elif op == 2 and live:
            kv.close_slot(live[rng.randint(len(live))])

        # invariants: conservation + disjointness + null page untouched
        tables = [kv.table(i) for i in range(max_slots)
                  if i not in kv.free_slots()]
        held = [b for t in tables for b in t.blocks]
        assert len(held) == len(set(held)), "block double-booked"
        assert 0 not in held, "null page allocated"
        assert len(held) + kv.allocator.num_free == usable, "leak"
        assert kv.allocator.peak_blocks_in_use >= len(held)
        st = kv.stats()
        assert 0 <= st["frag_frac"] <= 1 and st["frag_tokens"] >= 0
    for i in range(max_slots):
        if i not in kv.free_slots():
            kv.close_slot(i)
    assert kv.allocator.num_free == usable, "blocks not all returned"


@pytest.mark.parametrize("seed", range(5))
def test_block_allocator_invariants_random_ops(seed):
    _exercise_allocator(seed)


def test_block_allocator_invariants_hypothesis():
    """Same op machine driven by hypothesis where available; containers
    without it run a seeded sweep over the SAME parameter space instead
    of skipping — the invariants are checked either way."""
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(1234)
        for _ in range(30):
            _exercise_allocator(int(rng.randint(0, 2**16)),
                                num_blocks=int(rng.randint(3, 41)),
                                block_size=int(rng.randint(1, 9)),
                                steps=60)
        return

    @hyp.given(seed=st.integers(0, 2**16), blocks=st.integers(3, 40),
               bs=st.integers(1, 8))
    @hyp.settings(max_examples=30, deadline=None)
    def prop(seed, blocks, bs):
        _exercise_allocator(seed, num_blocks=blocks, block_size=bs,
                            steps=60)

    prop()


# --------------------------------------------------------------------------- #
# Prefix sharing: refcount/CoW state machine + index semantics
# --------------------------------------------------------------------------- #

def _drain_copies(kv, content):
    """Mirror the engine's CoW drain: device page copy -> host simulation."""
    for src, dst in kv.take_pending_copies():
        content[dst] = list(content.get(src, []))


def _exercise_sharing_machine(seed: int, num_blocks: int = 17,
                              block_size: int = 4, max_slots: int = 5,
                              steps: int = 300, vocab: int = 5):
    """Random open/append/close machine over the prefix-sharing cache with
    a host-side simulation of device page contents.  Invariants:

    * per-block refcount == number of live tables mapping the block; a
      block re-enters the free list only at refcount zero (no leak, no
      double-booking beyond the refcounts),
    * a position is only ever written into a block whose refcount is 1 —
      shared blocks are never mutated in place (CoW forked first),
    * every prefix-index hit maps blocks whose simulated contents equal
      the prompt (recycling never leaves stale entries behind),
    * closing everything returns the whole pool to the free list.

    A tiny token alphabet forces heavy prefix collision so the index,
    CoW, and cold-recycling paths all fire.
    """
    rng = np.random.RandomState(seed)
    kv = PagedKVCache(num_blocks=num_blocks, block_size=block_size,
                      max_slots=max_slots, max_blocks_per_seq=6)
    usable = kv.allocator.num_usable
    content: dict = {}                   # block -> tokens written, in order
    remaining: dict = {}                 # slot -> prompt tokens still to feed
    forks_seen = 0
    for _ in range(steps):
        op = rng.randint(3)
        free_slots = kv.free_slots()
        live = [i for i in range(max_slots) if i not in free_slots]
        if op == 0 and free_slots:
            plen = int(rng.randint(2, 3 * block_size))
            prompt = list(map(int, rng.randint(0, vocab, plen)))
            if not kv.can_admit(prompt):
                continue
            slot = free_slots[0]
            cached = kv.open_slot(slot, prompt)
            t = kv.table(slot)
            assert cached <= len(prompt) - 1, "last token must be recomputed"
            for p in range(cached):
                blk = t.blocks[p // block_size]
                assert content[blk][p % block_size] == prompt[p], \
                    "prefix-index hit served stale KV"
            remaining[slot] = prompt[cached:]
        elif op == 1 and live:
            slot = live[int(rng.randint(len(live)))]
            t = kv.table(slot)
            before_forks = kv.cow_forks
            if kv.ensure_capacity(slot):
                _drain_copies(kv, content)
                forks_seen += kv.cow_forks - before_forks
                tail = t.blocks[t.num_tokens // block_size]
                assert kv.allocator.refcount(tail) == 1, \
                    "write into a shared block (missed CoW fork)"
                rem = remaining.get(slot)
                tok = rem.pop(0) if rem else int(rng.randint(0, vocab))
                off = t.num_tokens % block_size
                buf = content.setdefault(tail, [])
                while len(buf) <= off:
                    buf.append(-1)
                buf[off] = tok
                kv.commit_token(slot, tok)
        elif op == 2 and live:
            slot = live[int(rng.randint(len(live)))]
            kv.close_slot(slot)
            remaining.pop(slot, None)

        # refcount accounting: each live table reference is one holder
        refs: dict = {}
        for i in range(max_slots):
            if i in kv.free_slots():
                continue
            for b in kv.table(i).blocks:
                refs[b] = refs.get(b, 0) + 1
        for b in range(1, num_blocks):
            assert kv.allocator.refcount(b) == refs.get(b, 0), \
                f"block {b}: refcount drift"
        assert 0 not in refs, "null page mapped"
        assert kv.allocator.blocks_in_use == len(refs)
        assert len(refs) + kv.allocator.num_free == usable, "leak"
        fl = kv.allocator._free
        assert len(fl) == len(set(fl)), "free-list duplicate"
    for i in range(max_slots):
        if i not in kv.free_slots():
            kv.close_slot(i)
    assert kv.allocator.num_free == usable, "blocks not all returned"
    return forks_seen


@pytest.mark.parametrize("seed", range(5))
def test_prefix_sharing_invariants_random_ops(seed):
    _exercise_sharing_machine(seed)


def test_prefix_sharing_invariants_sweep():
    """Hypothesis-style parameter sweep (seeded: the container may not
    ship hypothesis) — small pools force recycling of cached blocks, and
    across the sweep the CoW path must actually fire."""
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(99)
        forks = 0
        for _ in range(25):
            forks += _exercise_sharing_machine(
                int(rng.randint(0, 2**16)),
                num_blocks=int(rng.randint(5, 30)),
                block_size=int(rng.randint(2, 6)),
                steps=80)
        assert forks > 0, "sweep never exercised copy-on-write"
        return

    @hyp.given(seed=st.integers(0, 2**16), blocks=st.integers(5, 29),
               bs=st.integers(2, 5))
    @hyp.settings(max_examples=25, deadline=None)
    def prop(seed, blocks, bs):
        _exercise_sharing_machine(seed, num_blocks=blocks, block_size=bs,
                                  steps=80)

    prop()


def test_refcount_alloc_incref_decref_cold():
    a = BlockAllocator(num_blocks=5, block_size=4)
    [b] = a.alloc(1)
    assert a.refcount(b) == 1
    a.incref(b)
    assert a.refcount(b) == 2
    assert a.decref(b) == 1 and b not in a._free   # still held: not freed
    a.decref(b, cold=True)
    assert a.refcount(b) == 0 and a._free[0] == b  # parked cold (LIFO far end)
    with pytest.raises(ValueError):
        a.decref(b)                                # double free
    a.incref(b)                                    # revived from the free list
    assert a.refcount(b) == 1 and b not in a._free
    a.decref(b)
    assert a.num_free == a.num_usable


def test_prefix_hit_cow_fork_and_sole_holder_divergence():
    """Two sequences share a registered prefix; appending into the shared
    partial tail CoW-forks it (page copy queued, shared page untouched),
    while a sole holder diverges in place and just drops the entry."""
    kv = PagedKVCache(num_blocks=16, block_size=4, max_slots=3,
                      max_blocks_per_seq=6)
    sys_p = [1, 2, 3, 4, 5, 6]                     # 1.5 blocks
    kv.open_slot(0, sys_p)
    for tok in sys_p:
        assert kv.ensure_capacity(0)
        kv.commit_token(0, tok)
    b_full, b_tail = kv.table(0).blocks
    kv.close_slot(0)                               # registers [1..4] and (5,6)

    assert kv.open_slot(1, sys_p + [9, 9]) == 6    # full block + partial tail
    assert kv.open_slot(2, sys_p + [8, 8]) == 6
    t1, t2 = kv.table(1), kv.table(2)
    assert t1.blocks == [b_full, b_tail] == t2.blocks
    assert kv.allocator.refcount(b_tail) == 2

    assert kv.ensure_capacity(1)                   # write offset 2, shared
    assert kv.cow_forks == 1
    copies = kv.take_pending_copies()
    assert copies and copies[0][0] == b_tail
    fresh = copies[0][1]
    assert t1.blocks == [b_full, fresh]            # fork replaced the tail
    assert t2.blocks == [b_full, b_tail], "shared block mutated in place"
    kv.commit_token(1, 9)

    assert kv.ensure_capacity(2)                   # now sole holder of b_tail
    assert kv.cow_forks == 1 and not kv.pending_copies
    assert b_tail not in kv._node, "diverging tail must leave the index"
    kv.commit_token(2, 8)

    for s in (1, 2):
        kv.close_slot(s)
    assert kv.allocator.num_free == kv.allocator.num_usable


def test_recycled_cached_block_never_matches_stale():
    """Cached blocks park cold and are recycled last; once recycled their
    index entries (and descendants') are gone, so a later identical
    prompt recomputes instead of mapping stale pages."""
    kv = PagedKVCache(num_blocks=6, block_size=2, max_slots=2,
                      max_blocks_per_seq=5)
    prompt = [1, 2, 3, 4, 5, 6]                    # 3 full blocks
    kv.open_slot(0, prompt)
    for tok in prompt:
        assert kv.ensure_capacity(0)
        kv.commit_token(0, tok)
    kv.close_slot(0)
    assert len(kv.prefix_index) == 3               # chain cached, all cold
    # burn the whole pool with an unrelated prompt -> recycles cached blocks
    kv.open_slot(1)
    for tok in range(10, 10 + 2 * 5):
        assert kv.ensure_capacity(1)
        kv.commit_token(1, tok)
    kv.close_slot(1)
    assert kv.open_slot(0, prompt) == 0, "stale prefix entry survived"
    assert all(b not in kv._node or kv._node[b].parent == 0
               or kv._node[b].parent in kv._node
               for b in list(kv._node)), "dangling chain"
    kv.close_slot(0)


def test_allocator_oom_and_double_free():
    a = BlockAllocator(num_blocks=4, block_size=8)
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3] and a.num_free == 0
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free(got)
    with pytest.raises(ValueError):
        a.free([1])
    with pytest.raises(ValueError):
        a.free([0])


# --------------------------------------------------------------------------- #
# int8 KV blocks
# --------------------------------------------------------------------------- #

def test_quant8_kv_roundtrip_on_kv_blocks():
    """Per-vector symmetric int8 on KV-shaped pages: round-trip error is
    bounded by half a quantization step per element."""
    from repro.kernels.quant8.ops import dequantize_kv, quantize_kv
    x = jax.random.normal(jax.random.PRNGKey(0), (7, 16, 2, 64)) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.dtype == jnp.float32 and s.shape == x.shape[:-1]
    y = dequantize_kv(q, s, jnp.float32)
    step = np.asarray(s)[..., None]                # scale == amax / 127
    assert np.max(np.abs(np.asarray(y - x)) / step) <= 0.5 + 1e-6
    # all-zero vectors stay exactly zero (scale clamps to 1, not 0/0)
    q0, s0 = quantize_kv(jnp.zeros((3, 4, 1, 8)))
    assert np.all(np.asarray(q0) == 0) and np.all(np.asarray(s0) == 1.0)
    assert np.all(np.asarray(dequantize_kv(q0, s0, jnp.float32)) == 0)


@pytest.mark.parametrize("arch,patch", [
    ("qwen2-7b", dict(num_kv_heads=2)),          # GQA
    ("mixtral-8x7b", dict(sliding_window=6)),    # SWA + MoE
])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_paged_int8_cache_close_to_fp(arch, patch, impl):
    """Teacher-forcing through an int8 paged cache (quantize at append,
    dequantize inside the attention gather / Pallas kernel) tracks the
    fp32 cache within the quantization budget, for both decode impls."""
    cfg = _cfg(arch, **patch)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    B, S, bs = 2, 11, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    pools = {"fp": M.init_paged_cache(cfg, 12, bs, jnp.float32),
             "q": M.init_paged_cache(cfg, 12, bs, jnp.int8)}
    qleaf = [l for l in jax.tree.leaves(pools["q"]) if l.dtype == jnp.int8]
    assert qleaf, "int8 pool must store int8 pages"
    kv = PagedKVCache(num_blocks=12, block_size=bs, max_slots=B,
                      max_blocks_per_seq=4)
    for s in range(B):
        kv.open_slot(s)
    last = {}
    for i in range(S):
        for s in range(B):
            assert kv.ensure_capacity(s)
        bt = jnp.asarray(kv.device_tables())
        sl = jnp.asarray(kv.seq_lens())
        for name in pools:
            last[name], pools[name] = M.decode_step_paged(
                params, cfg, pools[name], prompt[:, i:i + 1], bt, sl,
                attn_impl=impl)
        for s in range(B):
            kv.commit_token(s)
    err = float(jnp.max(jnp.abs(last["q"] - last["fp"])))
    assert err <= 5e-2, f"{arch}/{impl}: int8 KV logits off by {err}"


def test_int8_pool_bytes_ratio():
    """int8 pages + fp32 per-vector scales weigh (D+4)/(2D) of the bf16
    pool — under the 0.55x acceptance bound for D >= 64."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    bf = sum(l.size * l.dtype.itemsize
             for l in jax.tree.leaves(M.init_paged_cache(cfg, 8, 16,
                                                         jnp.bfloat16)))
    q = sum(l.size * l.dtype.itemsize
            for l in jax.tree.leaves(M.init_paged_cache(cfg, 8, 16,
                                                        jnp.int8)))
    D = cfg.resolved_head_dim
    assert q / bf == pytest.approx((D + 4) / (2 * D))
    assert q / bf <= 0.55


def test_shared_prefix_pages_bit_identical_to_private():
    """A sequence admitted through the prefix index maps pages written by
    the ORIGINAL prefill; recomputing the same prompt privately (same
    chunking) produces bit-identical page contents — sharing changes
    where KV lives, never what it holds."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    bs = 4
    prompt = list(range(1, 10))                    # 2 full blocks + tail

    def prefill(kv, pages, slot, toks):
        for tok in toks:
            assert kv.ensure_capacity(slot)
            t = jnp.zeros((1, 1), jnp.int32).at[0, 0].set(tok)
            _, pages = M.decode_step_paged(
                params, cfg, pages, t, jnp.asarray(kv.device_tables()),
                jnp.asarray(kv.seq_lens()))
            kv.commit_token(slot, tok)
        return pages

    # sharing path: seq 0 prefills + closes, seq 1 re-opens the same prompt
    kv_s = PagedKVCache(num_blocks=12, block_size=bs, max_slots=1,
                        max_blocks_per_seq=4)
    pages_s = M.init_paged_cache(cfg, 12, bs, jnp.float32)
    kv_s.open_slot(0, prompt)
    pages_s = prefill(kv_s, pages_s, 0, prompt)
    kv_s.close_slot(0)
    cached = kv_s.open_slot(0, prompt)
    assert cached == len(prompt) - 1               # all but the last token
    shared_blocks = list(kv_s.table(0).blocks)

    # private path: fresh cache, sharing off
    kv_p = PagedKVCache(num_blocks=12, block_size=bs, max_slots=1,
                        max_blocks_per_seq=4, prefix_sharing=False)
    pages_p = M.init_paged_cache(cfg, 12, bs, jnp.float32)
    kv_p.open_slot(0)
    pages_p = prefill(kv_p, pages_p, 0, prompt)
    private_blocks = list(kv_p.table(0).blocks)

    leaves_s, leaves_p = jax.tree.leaves(pages_s), jax.tree.leaves(pages_p)
    compared = 0
    for ls, lp in zip(leaves_s, leaves_p):
        if ls.ndim < 4 or ls.shape[-3] != bs:
            continue                               # not a page pool leaf
        a, b = np.asarray(ls), np.asarray(lp)
        for bi in range(cached // bs):             # fully-cached blocks only
            sa = a[..., shared_blocks[bi], :, :, :]
            sb = b[..., private_blocks[bi], :, :, :]
            assert np.array_equal(sa, sb), "shared page != private recompute"
            compared += 1
    assert compared > 0


# --------------------------------------------------------------------------- #
# Engine end-to-end
# --------------------------------------------------------------------------- #

def _mixed_requests(cfg, n=5):
    prompts = [list(np.random.RandomState(i).randint(
        0, cfg.vocab_size, 3 + 3 * i)) for i in range(n)]
    max_new = [5 + (3 * i) % 7 for i in range(n)]
    return prompts, max_new, [
        Request(uid=f"r{i}", prompt=p, max_new=m)
        for i, (p, m) in enumerate(zip(prompts, max_new))]


def test_engine_matches_sequential_greedy():
    """Continuous batching (mixed lengths, fewer slots than requests)
    reproduces per-request dense greedy decoding exactly."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new, reqs = _mixed_requests(cfg)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=40, max_blocks_per_seq=10))
    out = eng.run(reqs)
    assert set(out) == {r.uid for r in reqs}
    for i, (p, m) in enumerate(zip(prompts, max_new)):
        ref = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), m)
        assert out[f"r{i}"].tokens == list(map(int, np.asarray(ref)[0, len(p):]))
    assert eng.kv.allocator.num_free == eng.kv.allocator.num_usable


def test_engine_preemption_under_memory_pressure():
    """A pool too small for all admitted sequences forces recompute
    preemption; results still match dense greedy and no blocks leak."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new, reqs = _mixed_requests(cfg)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=9, max_blocks_per_seq=8))
    out = eng.run(reqs)
    assert sum(c.preemptions for c in out.values()) > 0, \
        "pool was sized to force preemption"
    for i, (p, m) in enumerate(zip(prompts, max_new)):
        ref = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), m)
        assert out[f"r{i}"].tokens == list(map(int, np.asarray(ref)[0, len(p):]))
    assert eng.kv.allocator.num_free == eng.kv.allocator.num_usable


def test_engine_admission_rejects_oversized_request():
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=6, max_blocks_per_seq=4))
    with pytest.raises(ValueError):
        eng.submit(Request(uid="big", prompt=list(range(30)), max_new=10))
    with pytest.raises(ValueError):
        eng.submit(Request(uid="empty", prompt=[1, 2], max_new=0))


def test_engine_stats_window_and_frag_peaks():
    """reset_stats() starts a clean measurement window after warmup, and
    fragmentation/utilization are sampled at their per-step peaks (the
    instantaneous numbers are zero once every slot is evicted)."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=20, max_blocks_per_seq=8))
    eng.run([Request(uid="warm", prompt=[1, 2, 3], max_new=2)])
    warm_j = eng.monitor.total_j
    assert warm_j > 0
    eng.reset_stats()
    assert eng.monitor.total_j == 0 and eng.steps == 0
    assert not eng.completions
    eng.run([Request(uid="a", prompt=[5, 6, 7], max_new=4)])
    s = eng.stats()
    assert s["steps"] > 0 and s["energy_j"] > 0
    # prompt 3 + 4 new = 7 tokens in 4-token blocks -> tail slot unwritten
    assert s["frag_tokens_peak"] >= 1
    assert 0 < s["utilization_peak"] <= 1
    assert s["peak_cache_bytes"] > 0


def test_engine_token_by_token_mode_matches_greedy():
    """prefill_chunk=1 + sharing off is the pre-fast-path engine (the
    benchmark baseline); it must still match dense greedy exactly."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new, reqs = _mixed_requests(cfg)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=40, max_blocks_per_seq=10,
        prefill_chunk=1, prefix_sharing=False))
    out = eng.run(reqs)
    for i, (p, m) in enumerate(zip(prompts, max_new)):
        ref = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), m)
        assert out[f"r{i}"].tokens == list(map(int, np.asarray(ref)[0, len(p):]))


def test_engine_shared_prefix_workload_hits_and_saves():
    """Requests sharing a system prompt: later admissions map cached
    blocks (prefix_hit_rate > 0, KV bytes saved), outputs still match the
    unshared engine token for token, and nothing leaks."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    sys_p = list(np.random.RandomState(7).randint(0, cfg.vocab_size, 17))
    reqs = [Request(uid=f"r{i}",
                    prompt=sys_p + list(np.random.RandomState(50 + i)
                                        .randint(0, cfg.vocab_size, 3 + i)),
                    max_new=5)
            for i in range(4)]

    def run(sharing):
        eng = ServeEngine(params, cfg, EngineConfig(
            max_slots=2, block_size=4, num_blocks=64, max_blocks_per_seq=16,
            prefix_sharing=sharing))
        out = eng.run([dataclasses.replace(r) for r in reqs])
        return eng, out

    e_on, out_on = run(True)
    e_off, out_off = run(False)
    for r in reqs:
        assert out_on[r.uid].tokens == out_off[r.uid].tokens
    s = e_on.stats()
    assert s["prefix_hit_tokens"] > 0 and s["prefix_hit_rate"] > 0
    assert s["kv_bytes_saved"] > 0
    assert s["steps"] < e_off.stats()["steps"]
    assert e_on.kv.allocator.num_free == e_on.kv.allocator.num_usable


def test_engine_warmup_compiles_both_shapes_outside_window():
    """warmup() compiles the C=1 and C=chunk steps; reset_stats() zeroes
    the energy monitor so J/token prices serving, not XLA compilation —
    and the measured run triggers no further compiles."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=40, max_blocks_per_seq=10,
        prefill_chunk=4))
    eng.warmup()
    assert eng._step_fn._cache_size() == 1
    assert eng._chunk_fn._cache_size() == 1
    assert eng.monitor.total_j > 0 and "_warmup" not in eng.completions
    eng.reset_stats()
    assert eng.monitor.total_j == 0
    eng.run([Request(uid="a", prompt=list(range(1, 8)), max_new=4),
             Request(uid="b", prompt=[2, 3], max_new=3)])
    assert eng._step_fn._cache_size() == 1, "decode step recompiled"
    assert eng._chunk_fn._cache_size() == 1, "chunk step recompiled"
    s = eng.stats()
    assert s["energy_j"] > 0 and s["j_per_token"] > 0
    assert "inter_token_p99_s" in s


def test_engine_rejects_unpaged_architectures():
    cfg = tiny(get_config("mamba2-130m"))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    assert not M.paged_decode_supported(cfg)
    with pytest.raises(NotImplementedError):
        ServeEngine(params, cfg, EngineConfig(num_blocks=8))


# --------------------------------------------------------------------------- #
# Sampling
# --------------------------------------------------------------------------- #

def test_sampler_greedy_and_topk():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.RandomState(0).randn(4, 50), jnp.float32)
    # temperature 0 -> argmax
    out = sample_tokens(logits, key, jnp.zeros(4), jnp.zeros(4, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top-k restricts support to the k largest logits per row
    k = 3
    top = np.argsort(np.asarray(logits), axis=-1)[:, -k:]
    for s in range(20):
        out = sample_tokens(logits, jax.random.PRNGKey(s),
                            jnp.full(4, 1.0), jnp.full(4, k, jnp.int32))
        for b in range(4):
            assert int(out[b]) in top[b]


# --------------------------------------------------------------------------- #
# greedy_generate compile caching (satellite fix)
# --------------------------------------------------------------------------- #

def test_greedy_generate_reuses_jitted_step():
    cfg = _cfg("opt-125m")
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((1, 4), jnp.int32)
    jitted_decode_step.cache_clear()
    greedy_generate(params, cfg, prompt, max_new=2)
    info1 = jitted_decode_step.cache_info()
    greedy_generate(params, cfg, prompt, max_new=2)
    info2 = jitted_decode_step.cache_info()
    assert info2.misses == info1.misses == 1, "step re-built per call"
    assert info2.hits > info1.hits
    step = jitted_decode_step(cfg)
    assert step._cache_size() == 1, "decode step recompiled across calls"
