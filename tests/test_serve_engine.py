"""Paged-KV serving engine: cache parity, flash-decode numerics, block
allocator properties, continuous-batching end-to-end, sampler, and the
no-recompile contract of ``greedy_generate``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attention.chunked import chunked_attention
from repro.kernels.flash_attention.decode import (flash_decode_paged,
                                                 paged_attention_reference)
from repro.models import model as M
from repro.models import params as P
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.paged_cache import BlockAllocator, PagedKVCache, blocks_for
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.step import greedy_generate, jitted_decode_step

from conftest import tiny


def _cfg(arch, **patch):
    cfg = tiny(get_config(arch))
    return dataclasses.replace(cfg, **patch) if patch else cfg


# --------------------------------------------------------------------------- #
# Paged vs dense decode parity
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("arch,patch", [
    ("qwen2-7b", dict(num_kv_heads=2)),          # GQA (+ qkv bias)
    ("mixtral-8x7b", dict(sliding_window=6)),    # SWA + MoE decoder
    ("opt-125m", {}),                            # learned positions
])
def test_paged_vs_dense_decode_logits(arch, patch):
    """Teacher-forcing the same prompt through decode_step (dense cache)
    and decode_step_paged (block-table cache) yields identical logits."""
    cfg = _cfg(arch, **patch)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    B, S, bs = 2, 11, 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    dense = M.init_cache(cfg, B, 16, jnp.float32)
    kv = PagedKVCache(num_blocks=12, block_size=bs, max_slots=B,
                      max_blocks_per_seq=4)
    pages = M.init_paged_cache(cfg, 12, bs, jnp.float32)
    for s in range(B):
        kv.open_slot(s)

    for i in range(S):
        ld, dense = M.decode_step(params, cfg, dense, prompt[:, i:i + 1],
                                  jnp.int32(i))
        for s in range(B):
            assert kv.ensure_capacity(s)
        lp, pages = M.decode_step_paged(
            params, cfg, pages, prompt[:, i:i + 1],
            jnp.asarray(kv.device_tables()), jnp.asarray(kv.seq_lens()))
        for s in range(B):
            kv.commit_token(s)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lp),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"{arch} step {i}")


# --------------------------------------------------------------------------- #
# Flash-decode kernel numerics
# --------------------------------------------------------------------------- #

DECODE_CASES = [
    # H, K, D, bs, lens, window
    (4, 2, 64, 8, (17, 40), 0),          # GQA
    (4, 4, 32, 4, (1, 26), 0),           # MHA, fresh seq
    (8, 2, 64, 16, (33, 64), 20),        # GQA + sliding window
    (2, 1, 16, 4, (5, 12), 5),           # window < block
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_matches_chunked_reference(case):
    """Pallas flash-decode (interpret) over scattered pages == the chunked
    XLA flash kernel's last causal row over the equivalent dense KV."""
    H, K, D, bs, lens, window = case
    B = len(lens)
    nb = blocks_for(max(lens), bs)
    P_pool = B * nb + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pages = jax.random.normal(ks[1], (P_pool, bs, K, D))
    v_pages = jax.random.normal(ks[2], (P_pool, bs, K, D))
    # disjoint per-sequence block tables (page 0 = null)
    bt = (1 + np.arange(B * nb, dtype=np.int32)).reshape(B, nb)
    sl = jnp.asarray(lens, jnp.int32)

    out = flash_decode_paged(q, k_pages, v_pages, jnp.asarray(bt), sl,
                             window=window, pages_per_split=3,
                             interpret=True)
    ref = paged_attention_reference(q, k_pages, v_pages, jnp.asarray(bt),
                                    sl, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # cross-check against chunked.py on the gathered dense layout: the
    # decode output is the last causal row of full-sequence attention
    for b, L in enumerate(lens):
        kd = k_pages[bt[b]].reshape(-1, K, D)[None, :L]
        vd = v_pages[bt[b]].reshape(-1, K, D)[None, :L]
        qd = jnp.zeros((1, L, H, D)).at[:, L - 1].set(q[b])
        full = chunked_attention(qd, kd, vd, causal=True, window=window,
                                 chunk=8)
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(full[0, L - 1]),
                                   rtol=2e-5, atol=2e-5, err_msg=f"seq {b}")


# --------------------------------------------------------------------------- #
# Block allocator / paged-cache properties
# --------------------------------------------------------------------------- #

def _exercise_allocator(seed: int, num_blocks: int = 17, block_size: int = 4,
                        max_slots: int = 5, steps: int = 300):
    """Random alloc/append/free op machine; checks the paging invariants."""
    rng = np.random.RandomState(seed)
    kv = PagedKVCache(num_blocks=num_blocks, block_size=block_size,
                      max_slots=max_slots, max_blocks_per_seq=6)
    usable = kv.allocator.num_usable
    for _ in range(steps):
        op = rng.randint(3)
        free_slots = kv.free_slots()
        live = [i for i in range(max_slots) if i not in free_slots]
        if op == 0 and free_slots:
            kv.open_slot(free_slots[0])
        elif op == 1 and live:
            slot = live[rng.randint(len(live))]
            before = kv.allocator.num_free
            ok = kv.ensure_capacity(slot)
            if ok:
                kv.commit_token(slot)
                t = kv.table(slot)
                assert t.num_tokens <= t.allocated_tokens(block_size)
            else:
                # OOM must coincide with exhaustion (pool or table limit)
                t = kv.table(slot)
                assert (before == 0 or len(t.blocks) >= 6)
        elif op == 2 and live:
            kv.close_slot(live[rng.randint(len(live))])

        # invariants: conservation + disjointness + null page untouched
        tables = [kv.table(i) for i in range(max_slots)
                  if i not in kv.free_slots()]
        held = [b for t in tables for b in t.blocks]
        assert len(held) == len(set(held)), "block double-booked"
        assert 0 not in held, "null page allocated"
        assert len(held) + kv.allocator.num_free == usable, "leak"
        assert kv.allocator.peak_blocks_in_use >= len(held)
        st = kv.stats()
        assert 0 <= st["frag_frac"] <= 1 and st["frag_tokens"] >= 0
    for i in range(max_slots):
        if i not in kv.free_slots():
            kv.close_slot(i)
    assert kv.allocator.num_free == usable, "blocks not all returned"


@pytest.mark.parametrize("seed", range(5))
def test_block_allocator_invariants_random_ops(seed):
    _exercise_allocator(seed)


def test_block_allocator_invariants_hypothesis():
    """Same op machine driven by hypothesis where available (the container
    may not ship it; the seeded sweep above always runs)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hyp.given(seed=st.integers(0, 2**16), blocks=st.integers(3, 40),
               bs=st.integers(1, 8))
    @hyp.settings(max_examples=30, deadline=None)
    def prop(seed, blocks, bs):
        _exercise_allocator(seed, num_blocks=blocks, block_size=bs,
                            steps=60)

    prop()


def test_allocator_oom_and_double_free():
    a = BlockAllocator(num_blocks=4, block_size=8)
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3] and a.num_free == 0
    with pytest.raises(MemoryError):
        a.alloc(1)
    a.free(got)
    with pytest.raises(ValueError):
        a.free([1])
    with pytest.raises(ValueError):
        a.free([0])


# --------------------------------------------------------------------------- #
# Engine end-to-end
# --------------------------------------------------------------------------- #

def _mixed_requests(cfg, n=5):
    prompts = [list(np.random.RandomState(i).randint(
        0, cfg.vocab_size, 3 + 3 * i)) for i in range(n)]
    max_new = [5 + (3 * i) % 7 for i in range(n)]
    return prompts, max_new, [
        Request(uid=f"r{i}", prompt=p, max_new=m)
        for i, (p, m) in enumerate(zip(prompts, max_new))]


def test_engine_matches_sequential_greedy():
    """Continuous batching (mixed lengths, fewer slots than requests)
    reproduces per-request dense greedy decoding exactly."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new, reqs = _mixed_requests(cfg)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=40, max_blocks_per_seq=10))
    out = eng.run(reqs)
    assert set(out) == {r.uid for r in reqs}
    for i, (p, m) in enumerate(zip(prompts, max_new)):
        ref = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), m)
        assert out[f"r{i}"].tokens == list(map(int, np.asarray(ref)[0, len(p):]))
    assert eng.kv.allocator.num_free == eng.kv.allocator.num_usable


def test_engine_preemption_under_memory_pressure():
    """A pool too small for all admitted sequences forces recompute
    preemption; results still match dense greedy and no blocks leak."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new, reqs = _mixed_requests(cfg)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=9, max_blocks_per_seq=8))
    out = eng.run(reqs)
    assert sum(c.preemptions for c in out.values()) > 0, \
        "pool was sized to force preemption"
    for i, (p, m) in enumerate(zip(prompts, max_new)):
        ref = greedy_generate(params, cfg, jnp.asarray([p], jnp.int32), m)
        assert out[f"r{i}"].tokens == list(map(int, np.asarray(ref)[0, len(p):]))
    assert eng.kv.allocator.num_free == eng.kv.allocator.num_usable


def test_engine_admission_rejects_oversized_request():
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=6, max_blocks_per_seq=4))
    with pytest.raises(ValueError):
        eng.submit(Request(uid="big", prompt=list(range(30)), max_new=10))
    with pytest.raises(ValueError):
        eng.submit(Request(uid="empty", prompt=[1, 2], max_new=0))


def test_engine_stats_window_and_frag_peaks():
    """reset_stats() starts a clean measurement window after warmup, and
    fragmentation/utilization are sampled at their per-step peaks (the
    instantaneous numbers are zero once every slot is evicted)."""
    cfg = _cfg("qwen2-7b", num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=20, max_blocks_per_seq=8))
    eng.run([Request(uid="warm", prompt=[1, 2, 3], max_new=2)])
    warm_j = eng.monitor.total_j
    assert warm_j > 0
    eng.reset_stats()
    assert eng.monitor.total_j == 0 and eng.steps == 0
    assert not eng.completions
    eng.run([Request(uid="a", prompt=[5, 6, 7], max_new=4)])
    s = eng.stats()
    assert s["steps"] > 0 and s["energy_j"] > 0
    # prompt 3 + 4 new = 7 tokens in 4-token blocks -> tail slot unwritten
    assert s["frag_tokens_peak"] >= 1
    assert 0 < s["utilization_peak"] <= 1
    assert s["peak_cache_bytes"] > 0


def test_engine_rejects_unpaged_architectures():
    cfg = tiny(get_config("mamba2-130m"))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    assert not M.paged_decode_supported(cfg)
    with pytest.raises(NotImplementedError):
        ServeEngine(params, cfg, EngineConfig(num_blocks=8))


# --------------------------------------------------------------------------- #
# Sampling
# --------------------------------------------------------------------------- #

def test_sampler_greedy_and_topk():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.RandomState(0).randn(4, 50), jnp.float32)
    # temperature 0 -> argmax
    out = sample_tokens(logits, key, jnp.zeros(4), jnp.zeros(4, jnp.int32))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top-k restricts support to the k largest logits per row
    k = 3
    top = np.argsort(np.asarray(logits), axis=-1)[:, -k:]
    for s in range(20):
        out = sample_tokens(logits, jax.random.PRNGKey(s),
                            jnp.full(4, 1.0), jnp.full(4, k, jnp.int32))
        for b in range(4):
            assert int(out[b]) in top[b]


# --------------------------------------------------------------------------- #
# greedy_generate compile caching (satellite fix)
# --------------------------------------------------------------------------- #

def test_greedy_generate_reuses_jitted_step():
    cfg = _cfg("opt-125m")
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((1, 4), jnp.int32)
    jitted_decode_step.cache_clear()
    greedy_generate(params, cfg, prompt, max_new=2)
    info1 = jitted_decode_step.cache_info()
    greedy_generate(params, cfg, prompt, max_new=2)
    info2 = jitted_decode_step.cache_info()
    assert info2.misses == info1.misses == 1, "step re-built per call"
    assert info2.hits > info1.hits
    step = jitted_decode_step(cfg)
    assert step._cache_size() == 1, "decode step recompiled across calls"
