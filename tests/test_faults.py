"""Fault tolerance: deterministic fault plans, bounded-staleness async
local SGD (including the exact reduction to the sync loop), self-healing
checkpoint restore, orchestrator/serve fault consumption, and the
fault-event telemetry schema."""

import json
import shutil
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import (CheckpointSpec, HealReport, RestorePolicy,
                              ShardChecksumError, ShardReadError, ckpt,
                              heal_cost)
from repro.configs.opt import opt_config
from repro.core.faultinject import FaultInjector, FaultPlan, corrupt_file
from repro.core.net import NetParams, Topology
from repro.core.sched.orchestrator import Orchestrator, SimConfig, make_fleet
from repro.models import params as P
from repro.obs.validate import validate_chrome_trace, validate_metrics_jsonl
from repro.optim import adamw
from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
from repro.train.trainer import TrainerConfig

L = 4


def _cfg():
    return opt_config("opt-125m").reduced(num_layers=L, d_model=32,
                                          vocab_size=64)


def _tc(steps=4, seed=0):
    return TrainerConfig(steps=steps, batch=2, seq_len=16, log_every=0,
                         seed=seed)


def _ls(**kw):
    base = dict(replicas=2, inner_steps=2, nominal_step_s=0.1)
    base.update(kw)
    return LocalSGDConfig(**base)


# ---------------------------------------------------------------- fault plan

def test_fault_plan_draws_are_stateless_and_replayable():
    p = FaultPlan(seed=3, straggler_frac=0.5, crash_prob=0.3,
                  link_flap_prob=0.4, corrupt_prob=0.2)
    q = FaultPlan(seed=3, straggler_frac=0.5, crash_prob=0.3,
                  link_flap_prob=0.4, corrupt_prob=0.2)
    # identical plans agree draw-for-draw; draw order cannot matter
    # because every draw is keyed by (seed, kind, entity, t)
    for r in range(6):
        assert p.slowdown(r) == q.slowdown(r)
        for t in range(6):
            assert p.crashes(r, t) == q.crashes(r, t)
            assert p.jitter_s(r, t) == q.jitter_s(r, t)
            assert p.corrupts(t, r, "h") == q.corrupts(t, r, "h")
    # interleaving other consumers' draws perturbs nothing
    before = p.slowdown(0)
    p.crashes("serve-req-9", 4), p.corrupts(1, 2, "n3")
    assert p.slowdown(0) == before
    # a different seed is a different schedule
    r = FaultPlan(seed=4, straggler_frac=0.5)
    assert any(p.slowdown(i) != r.slowdown(i) for i in range(16))
    assert not FaultPlan(seed=3).active and p.active


def test_injector_emits_schema_and_rejects_unknown_kinds():
    inj = FaultInjector(FaultPlan(seed=0, crash_prob=1.0))
    inj.emit("crash", 3, ts_s=1.0, round=2)
    inj.emit("crash", 3)
    inj.emit("heal", "n1", shards=2)
    assert inj.counts == {"crash": 2, "heal": 1}
    assert inj.registry.counter("faults/crash").value == 2
    with pytest.raises(ValueError):
        inj.emit("meteor_strike", 0)
    # pass-through to the plan
    assert inj.crashes(0, 0) == inj.plan.crashes(0, 0)


def test_corrupt_file_is_deterministic_and_header_preserving(tmp_path):
    f = tmp_path / "x.npy"
    arr = np.arange(256, dtype=np.float32)
    np.save(f, arr)
    orig = f.read_bytes()
    corrupt_file(f, seed=9)
    rot_a = f.read_bytes()
    f.write_bytes(orig)
    corrupt_file(f, seed=9)
    assert f.read_bytes() == rot_a != orig
    assert rot_a[:128] == orig[:128]          # .npy header still parses
    back = np.load(f)                         # loads fine -- silent rot
    assert not np.array_equal(back, arr)


# ------------------------------------------------- async local SGD reduction

def test_async_q_all_s0_bit_identical_to_sync():
    """The property hypothesis drives below, pinned at the defaults."""
    cfg, tc = _cfg(), _tc()
    sync = train_local_sgd(cfg, tc, _ls())
    asyn = train_local_sgd(cfg, tc, _ls(async_mode=True))
    assert asyn.mode == "async" and sync.mode == "sync"
    assert asyn.losses == sync.losses
    assert asyn.round_losses == sync.round_losses
    assert asyn.outer_updates == sync.outer_updates == sync.rounds


def test_async_reduces_to_sync_property():
    """hypothesis: for any (seed, replicas), quorum=all + staleness 0
    makes the async engine bit-identical to the synchronous loop."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    cfg = _cfg()

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 2 ** 16 - 1), replicas=st.integers(1, 3))
    def prop(seed, replicas):
        tc = _tc(seed=seed)
        sync = train_local_sgd(cfg, tc, _ls(replicas=replicas))
        asyn = train_local_sgd(cfg, tc, _ls(replicas=replicas,
                                            async_mode=True,
                                            quorum=replicas,
                                            staleness_bound=0))
        assert asyn.losses == sync.losses
        assert asyn.round_losses == sync.round_losses

    prop()


def test_async_fault_replay_is_bit_identical():
    cfg, tc = _cfg(), _tc(steps=8)
    plan = FaultPlan(seed=16, straggler_frac=0.5, crash_prob=0.4,
                     link_flap_prob=0.3)
    ls = _ls(replicas=3, async_mode=True, quorum=2, staleness_bound=1)
    a = train_local_sgd(cfg, tc, ls, fault_plan=plan)
    b = train_local_sgd(cfg, tc, ls, fault_plan=plan)
    assert a.losses == b.losses
    assert a.round_losses == b.round_losses
    assert a.fault_counts == b.fault_counts
    assert a.virtual_time_s == b.virtual_time_s
    assert a.dropped_stale == b.dropped_stale
    assert a.crashes == b.crashes and a.crashes >= 1   # seed 16 crashes
    assert a.fault_counts.get("rejoin", 0) >= 1
    assert a.resyncs >= 1


def test_async_staleness_bound_drops_straggler_work():
    """seed 0 @ frac 0.5 makes replicas 0/1 stragglers and 2 fast; with
    quorum=1 and S=0 a slow replica's delta is always a version behind
    when it lands -- dropped at the bound, replica re-synced."""
    cfg, tc = _cfg(), _tc(steps=16)
    plan = FaultPlan(seed=0, straggler_frac=0.5)
    assert plan.is_straggler(0) and not plan.is_straggler(2)
    res = train_local_sgd(cfg, tc, _ls(replicas=3, async_mode=True,
                                       quorum=1, staleness_bound=0),
                          fault_plan=plan)
    assert res.dropped_stale >= 1
    assert res.resyncs >= res.dropped_stale
    assert res.fault_counts.get("drop_stale", 0) == res.dropped_stale
    # dropped work ran but never merged
    assert res.contributed_steps < res.inner_steps_total


def test_async_beats_sync_clock_under_stragglers():
    """Quorum gating stops the slowest device from stalling every round:
    the modelled fleet clock yields more contributed tokens/s async."""
    cfg, tc = _cfg(), _tc(steps=8)
    plan = FaultPlan(seed=0, straggler_frac=0.5)     # 4-8x stragglers
    sync = train_local_sgd(cfg, tc, _ls(replicas=3), fault_plan=plan)
    asyn = train_local_sgd(cfg, tc, _ls(replicas=3, async_mode=True,
                                        quorum=2, staleness_bound=2),
                           fault_plan=plan)
    assert sync.losses == train_local_sgd(cfg, tc, _ls(replicas=3)).losses, \
        "sync trajectory must not depend on the fault plan"
    assert asyn.virtual_tokens_per_s > sync.virtual_tokens_per_s


def test_async_rejects_bad_knobs_and_monitor():
    cfg, tc = _cfg(), _tc()
    with pytest.raises(ValueError):
        train_local_sgd(cfg, tc, _ls(async_mode=True, quorum=5))
    with pytest.raises(ValueError):
        train_local_sgd(cfg, tc, _ls(async_mode=True, staleness_bound=-1))
    from repro.core.energy.devices import get_device
    from repro.core.energy.monitor import ComponentModel, EnergyMonitor
    mon = EnergyMonitor(ComponentModel.for_device(get_device("laptop-m2pro")))
    with pytest.raises(ValueError):
        train_local_sgd(cfg, tc, _ls(async_mode=True), monitor=mon)


# ------------------------------------------------- self-healing checkpoints

def _state(cfg, seed=0):
    params = P.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init_opt_state(params, adamw.OptConfig())
    return {"params": params, "opt": opt}


def _assert_bitexact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.dtype.kind == "V":
            xa, ya = xa.view(np.uint16), ya.view(np.uint16)
        np.testing.assert_array_equal(xa, ya)


def test_checksums_catch_silent_corruption(tmp_path):
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save(str(tmp_path), 3, tree)
    files = sorted(p for p in (tmp_path / "step_00000003").iterdir()
                   if p.suffix == ".npy")
    corrupt_file(files[0], seed=1)
    corrupt_file(files[1], seed=1)
    assert len(ckpt.damaged_files(str(tmp_path), 3)) == 2
    with pytest.raises(ShardReadError) as ei:
        ckpt.restore(str(tmp_path), tree, step=3)
    assert "2 shard file(s) unreadable" in str(ei.value)
    # checksum mismatches are deterministic bit-rot: not retried
    pol = RestorePolicy(retries=5)
    with pytest.raises(ShardChecksumError):
        ckpt._load_array(files[0], np.float32,
                         crc=ckpt._read_manifest(
                             tmp_path / "step_00000003")["checksums"]
                         [files[0].name], policy=pol)


def test_heal_refetches_corrupt_and_missing_shards(tmp_path):
    """Corrupt two shard files and delete a third in the primary copy;
    restore with a neighbour-holder source heals all three bit-exactly
    and reports the fetched bytes per source."""
    cfg = _cfg()
    tree = _state(cfg)
    primary, holder = tmp_path / "primary", tmp_path / "holder"
    spec = CheckpointSpec(L, (0, 1, 2, L), replication=1)
    ckpt.save_for_placement(str(primary), 5, tree, spec)
    shutil.copytree(primary, holder)
    files = sorted(p for p in (primary / "step_00000005").iterdir()
                   if p.suffix == ".npy")
    corrupt_file(files[0], seed=2)
    corrupt_file(files[1], seed=2)
    files[2].unlink()
    rep = HealReport()
    back = ckpt.restore(str(primary), tree, step=5,
                        sources=[("n1", str(holder))], heal_report=rep)
    _assert_bitexact(tree, back)
    assert rep.ok and len(rep.healed) == 3 and not rep.unrecovered
    assert rep.bytes_fetched > 0
    assert rep.per_source_bytes == {"n1": rep.bytes_fetched}
    reasons = {h["reason"] for h in rep.healed}
    assert reasons == {"corrupt", "missing"}
    # the primary is repaired in place: a plain restore now succeeds
    _assert_bitexact(tree, ckpt.restore(str(primary), tree, step=5))


def test_heal_reports_unrecovered_without_a_clean_source(tmp_path):
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save(str(tmp_path / "a"), 1, tree)
    files = sorted(p for p in (tmp_path / "a" / "step_00000001").iterdir()
                   if p.suffix == ".npy")
    corrupt_file(files[0], seed=3)
    rep = ckpt.heal_step(str(tmp_path / "a"), 1,
                         sources=[str(tmp_path / "nope")])
    assert not rep.ok and rep.unrecovered and not rep.healed


def test_heal_cost_prices_fetches_over_topology():
    topo = Topology(params=NetParams(wan_bw_Bps=5e6))
    from repro.core.energy.devices import LAPTOP_M2PRO
    topo.add_device("a", "europe", LAPTOP_M2PRO)
    topo.add_device("b", "europe", LAPTOP_M2PRO)
    topo.add_device("c", "north_america", LAPTOP_M2PRO)
    from repro.checkpoint.elastic import STORE
    c = heal_cost(topo, [("a", "b", 1e6), ("a", "c", 2e6),
                         (STORE, "b", 5e5)])
    assert c.bytes_moved == pytest.approx(3.5e6)
    assert c.wan_bytes == pytest.approx(2.5e6)   # cross-region + store
    assert c.time_s > 0 and c.transfers == 3


def test_restore_retry_aggregates_every_unreadable_shard(tmp_path):
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save_for_placement(str(tmp_path), 2, tree,
                            CheckpointSpec(L, (0, 2, L)))
    step_dir = tmp_path / "step_00000002"
    files = sorted(p for p in step_dir.iterdir() if p.suffix == ".npy")
    for f in files[:3]:
        corrupt_file(f, seed=4)
    with pytest.raises(ShardReadError) as ei:
        ckpt.restore(str(tmp_path), tree, step=2,
                     policy=RestorePolicy(retries=1, backoff_s=0.0))
    msg = str(ei.value)
    assert "unreadable after 1 retries" in msg
    assert "CRC32 mismatch" in msg
    assert isinstance(ei.value, ckpt.IncompleteCheckpointError)


# ------------------------------------------------------------- orchestrator

def test_sim_replays_identically_under_fault_plan():
    """Satellite contract: identical SimConfigs (seed + plan) replay
    identical trajectories -- membership churn included."""
    cfg = opt_config("opt-125m")
    plan = FaultPlan(seed=0, straggler_frac=0.3, crash_prob=0.02,
                     link_flap_prob=0.1, corrupt_prob=0.3)
    sim = SimConfig(total_steps=60, seed=5, checkpoint_interval=20,
                    fault_plan=plan)
    fa = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    fb = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    a = Orchestrator(cfg, fa, sim).run()
    b = Orchestrator(cfg, fb, sim).run()
    assert a.wall_time_s == b.wall_time_s
    assert a.energy_wh == b.energy_wh
    assert a.membership_changes == b.membership_changes
    assert a.fault_counts == b.fault_counts
    # seed 0 exercises every path: stragglers stretch compute, crashes
    # force churn, corrupt shard copies degrade recovery to other
    # holders (the heal events)
    assert a.crashes >= 1 and a.fault_counts.get("rejoin", 0) >= 1
    assert a.corrupted_shard_copies >= 1
    assert a.fault_counts.get("heal", 0) >= 1
    assert a.steps_done == 60


def test_sim_without_plan_matches_legacy_seeding():
    """fault_plan=None must not perturb the churn streams: the named
    substreams draw exactly what the old shared RNG schedule drew."""
    cfg = opt_config("opt-125m")
    fa = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    fb = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    a = Orchestrator(cfg, fa, SimConfig(total_steps=40, seed=5)).run()
    b = Orchestrator(cfg, fb, SimConfig(total_steps=40, seed=5,
                                        fault_plan=FaultPlan())).run()
    assert a.wall_time_s == b.wall_time_s
    assert a.membership_changes == b.membership_changes
    assert b.fault_counts == {}


# -------------------------------------------------------------------- serve

def _serve_cfg():
    from repro.configs import get_config
    from conftest import tiny
    return tiny(get_config("opt-125m"))


def test_serve_ttft_deadline_fails_gracefully():
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = _serve_cfg()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=1, block_size=4, num_blocks=16, max_blocks_per_seq=8,
        ttft_deadline_s=0.02))
    eng.submit(Request(uid="x", prompt=[1, 2, 3], max_new=6))
    for _ in range(4):                       # x admitted, producing tokens
        eng.step()
    eng.submit(Request(uid="y", prompt=[4, 5], max_new=4))
    time.sleep(0.03)                         # y queued past its deadline
    out = eng.run()
    assert out["y"].failed and out["y"].fail_reason == "deadline"
    assert out["y"].tokens == []
    assert not out["x"].failed and len(out["x"].tokens) == 6
    s = eng.stats()
    assert s["deadline_failures"] == 1 and s["requests_failed"] == 1


def test_serve_requeue_limit_bounds_injected_churn():
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = _serve_cfg()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    plan = FaultPlan(seed=1, crash_prob=0.6)
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=24, max_blocks_per_seq=8,
        max_requeues=2), fault_plan=plan)
    out = eng.run([Request(uid="a", prompt=[1, 2, 3], max_new=8),
                   Request(uid="b", prompt=[7, 8], max_new=8)])
    failed = [c for c in out.values() if c.failed]
    assert failed, "crash_prob=0.6 must trip the requeue bound"
    assert all(c.fail_reason == "requeue_limit" for c in failed)
    assert eng.injector.counts.get("crash", 0) >= 3
    s = eng.stats()
    assert s["requeue_limit_failures"] == len(failed)
    # replay: the same plan produces the same failures
    eng2 = ServeEngine(params, cfg, EngineConfig(
        max_slots=2, block_size=4, num_blocks=24, max_blocks_per_seq=8,
        max_requeues=2), fault_plan=plan)
    out2 = eng2.run([Request(uid="a", prompt=[1, 2, 3], max_new=8),
                     Request(uid="b", prompt=[7, 8], max_new=8)])
    assert {u: (c.failed, tuple(c.tokens)) for u, c in out.items()} == \
        {u: (c.failed, tuple(c.tokens)) for u, c in out2.items()}


# -------------------------------------------------------- telemetry schema

def test_validate_checks_fault_event_schema(tmp_path):
    from repro.obs.trace import Tracer
    tr = Tracer(enabled=True, process="test")
    with tr.span("work", "test"):
        pass
    inj = FaultInjector(FaultPlan(seed=0, crash_prob=1.0))
    inj.tracer = tr
    inj.emit("crash", 7, ts_s=1.0, round=3)
    inj.emit("heal", "n2", shards=2)
    good = tmp_path / "trace.json"
    tr.save_chrome_trace(str(good))
    counts = validate_chrome_trace(str(good))
    assert counts["fault"] == 2
    # a fault-cat event without the schema fails validation
    data = json.loads(good.read_text())
    data["traceEvents"].append({"name": "oops", "cat": "fault", "ph": "i",
                                "ts": 0, "pid": 1, "tid": 1, "args": {}})
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(data))
    with pytest.raises(ValueError, match="fault"):
        validate_chrome_trace(str(bad))
    # raw jsonl event log: same schema check
    lines = [
        json.dumps({"name": "fault.crash", "cat": "fault", "ph": "i",
                    "ts_us": 10, "args": {"entity": "7"}}),
        json.dumps({"name": "step", "cat": "train", "ph": "X",
                    "ts_us": 0, "dur_us": 5}),
    ]
    jl = tmp_path / "events.jsonl"
    jl.write_text("\n".join(lines) + "\n")
    jcounts = validate_metrics_jsonl(str(jl))
    assert jcounts["fault"] == 1 and jcounts["event"] == 2
    jl.write_text(json.dumps({"name": "fault.", "cat": "fault", "ph": "i",
                              "ts_us": 0, "args": {"entity": "x"}}) + "\n")
    with pytest.raises(ValueError, match="bad name"):
        validate_metrics_jsonl(str(jl))
