"""DT-FM shard_map pipeline: correctness + learning on a simulated mesh.

The pipeline path deadlocked when dispatched eagerly (XLA CPU rendezvous —
threads reach different collectives in different orders), so the step is
jitted inside ``pipeline_train_step``; these tests pin that and the
schedule's equivalence with a plain forward pass.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.opt import opt_config
from repro.data.pipeline import make_batch_fn
from repro.distributed.pipeline import (make_pipeline_loss,
                                        pipeline_train_step,
                                        stack_for_stages, unstack_stages)
from repro.models import model as M
from repro.models import params as P
from repro.optim import adamw


def _tiny_cfg():
    return dataclasses.replace(
        opt_config("opt-125m"), name="opt-pipe-test", num_layers=4,
        d_model=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
        vocab_size=512)


def test_pipeline_loss_matches_plain_forward():
    """GPipe schedule over 2 stages == unpipelined forward loss."""
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((1, 2), ("data", "stage"))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    ref_loss, _ = M.forward_train(params, cfg, batch)

    loss_fn = make_pipeline_loss(cfg, mesh, num_microbatches=2)
    staged = stack_for_stages(cfg, params, 2)
    with compat.set_mesh(mesh):
        pipe_loss = jax.jit(loss_fn)(params, staged, batch)
    np.testing.assert_allclose(float(pipe_loss), float(ref_loss),
                               rtol=5e-3)


def test_pipeline_trains():
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((2, 2), ("data", "stage"))
    opt_cfg = adamw.OptConfig(learning_rate=1e-3, warmup_steps=5,
                              decay_steps=40)
    init_fn, step_fn = pipeline_train_step(cfg, mesh, opt_cfg,
                                           num_microbatches=2)
    with compat.set_mesh(mesh):
        rest, staged, opt = init_fn(jax.random.PRNGKey(0))
        data = make_batch_fn(cfg, 4, 32, seed=0)
        losses = []
        for _ in range(25):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            rest, staged, opt, metrics = step_fn(rest, staged, opt, batch)
            losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    # round-trip the staging
    back = unstack_stages(cfg, staged)
    assert back["s0_attn"]["wq"].shape[0] == cfg.num_layers
