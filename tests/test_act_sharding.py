"""Activation-sharding constraint tests.

Regression for the silent-no-op bug: ``constrain`` compared
``str(AxisType.Auto) == "Auto"`` which is never true, so every activation
constraint in the framework lowered to nothing (16x replicated attention
on the production mesh — EXPERIMENTS.md §Perf #1).  These tests pin the
contract: constraints must appear in the lowered IR, priority must pick
the first dividing dim, and sharded programs must match unsharded ones
numerically.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.distributed.act_sharding import (BATCH, MODEL, axis_extent,
                                            constrain)


def _mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


def _lowered_constraints(fn, *args):
    with compat.set_mesh(_mesh()):
        txt = jax.jit(fn).lower(*args).as_text()
    return [ln for ln in txt.splitlines()
            if "sharding_constraint" in ln or "mhlo.sharding" in ln]


def test_constraint_reaches_ir():
    x = jax.ShapeDtypeStruct((4, 16, 64), jnp.float32)
    lines = _lowered_constraints(
        lambda x: constrain(x, BATCH, MODEL, None).sum(), x)
    assert lines, "constrain() lowered to nothing (AxisType regression)"
    if compat.SHARDY_IR:
        assert any("data" in ln and "model" in ln for ln in lines)
    else:
        # GSPMD IR (jax 0.4.x): device-list form of the same (2, 4, 1) split
        assert any("devices=[2,4,1]" in ln for ln in lines), lines


def test_priority_picks_first_dividing_dim():
    # dims: (batch=4, a=3, b=8, c=64): model extent 4 -> 'a' skipped (3%4),
    # 'b' gets it (8%4==0), 'c' must stay unconstrained
    x = jax.ShapeDtypeStruct((4, 3, 8, 64), jnp.float32)
    lines = _lowered_constraints(
        lambda x: constrain(x, BATCH, MODEL, MODEL, MODEL).sum(), x)
    assert lines
    if compat.SHARDY_IR:
        (ln,) = [l for l in lines if "sharding_constraint" in l]
        # dim1 unconstrained, dim2 model
        assert '{"data"}, {?}, {"model"}, {?}' in ln, ln
    else:
        # GSPMD: dims 1/3 unspecified, dim0 data(2), dim2 model(4)
        (ln,) = [l for l in lines if "mhlo.sharding" in l]
        assert "devices=[2,1,4,1]" in ln, ln
        assert "unspecified_dims=[1,3]" in ln, ln


def test_axis_extent():
    with compat.set_mesh(_mesh()):
        def f(x):
            assert axis_extent("model") == 4
            assert axis_extent("data") == 2
            assert axis_extent("pod") == 1
            return x
        jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    assert axis_extent("model") == 1   # no ambient mesh


def test_sharded_matches_unsharded_numerics():
    from repro.kernels.flash_attention.chunked import chunked_attention
    B, S, H, K, D = 2, 256, 8, 4, 32
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D), jnp.float32)
    ref = chunked_attention(q, k, v, causal=True, chunk=64)
    with compat.set_mesh(_mesh()):
        out = jax.jit(lambda q, k, v: chunked_attention(
            q, k, v, causal=True, chunk=64))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_kv_expand_matches_grouped():
    """The TP kv-head expansion (H % TP == 0 but K, g % TP != 0) must be
    numerically identical to the grouped path."""
    from repro.kernels.flash_attention.chunked import chunked_attention
    # H=8 divides model extent 4; K=2 and g=4 both... g=4 divides; pick
    # H=8, K=2, g=4 on extent 8? Use mesh (1, 8): H=8%8==0, K=2%8!=0, g=4%8!=0
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    B, S, H, K, D = 2, 128, 8, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, D), jnp.float32)
    ref = chunked_attention(q, k, v, causal=True, chunk=32)   # no mesh
    with compat.set_mesh(mesh):
        out = jax.jit(lambda q, k, v: chunked_attention(
            q, k, v, causal=True, chunk=32))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("layout", ["auto", "unconstrained"])
def test_moe_layout_numerics_match(layout):
    """MoE dispatch output must not depend on the expert-parallel layout."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M, params as P
    cfg = get_config("mixtral-8x7b").reduced(num_layers=2, d_model=64,
                                             vocab_size=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, layout=layout))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out = M.forward_logits(params, cfg, {"tokens": toks})
    assert np.isfinite(np.asarray(out)).all()
    # layouts must agree with the default
    cfg0 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, layout="auto"))
    out0 = M.forward_logits(params, cfg0, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(out), np.asarray(out0),
                               rtol=1e-6, atol=1e-6)


def test_whisper_cross_kv_cache_matches_legacy():
    """Warmed cross-KV decode must equal the legacy re-projection path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M, params as P
    cfg = get_config("whisper-medium").reduced(num_layers=2, d_model=64,
                                               vocab_size=128)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.encoder_seq_len, cfg.d_model),
                               jnp.float32)
    enc = M.encoder_forward(params, cfg, frames, {})

    legacy = M.init_cache(cfg, B, S, dtype=jnp.float32)
    warmed = M.warm_cross_cache(params, cfg,
                                M.init_cache(cfg, B, S, dtype=jnp.float32),
                                enc)
    for i in range(S):
        t = toks[:, i:i + 1]
        lg_a, legacy = M.decode_step(params, cfg, legacy, t, jnp.int32(i),
                                     enc=enc)
        lg_b, warmed = M.decode_step(params, cfg, warmed, t, jnp.int32(i))
        # legacy projects K/V fresh in f32; the warmed path round-trips
        # K/V through the cache dtype and the bf16 attention inputs —
        # agreement is bounded by bf16 resolution, not exact
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   rtol=0.05, atol=0.05)


def test_vocab_padding_masks_and_divides():
    """Vocab padding (beyond-paper #8): padded logits are -inf, argmax and
    loss unaffected, and the padded vocab divides any TP extent <=128."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M, params as P
    for arch in ("mamba2-130m", "granite-3-2b"):
        cfg = get_config(arch)
        assert cfg.padded_vocab_size % 128 == 0
        assert cfg.padded_vocab_size >= cfg.vocab_size

    cfg = get_config("mamba2-130m").reduced(num_layers=2, d_model=64,
                                            vocab_size=100)
    assert cfg.padded_vocab_size == 128
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 100)
    logits = M.forward_logits(params, cfg, {"tokens": toks})
    assert logits.shape[-1] == 128
    pad = np.asarray(logits)[..., 100:]
    assert (pad <= -1e29).all(), "pad region must be masked to -inf"
    # loss is finite and gradients flow
    loss, _ = M.cross_entropy(logits, toks)
    assert np.isfinite(float(loss))
