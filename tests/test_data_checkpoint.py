"""Data pipeline + sharded/replicated checkpoint tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.opt import opt_config
from repro.data.pipeline import DataConfig, SyntheticLM, make_batch_fn
from repro.models import params as P


def test_stream_deterministic_and_in_vocab():
    c = DataConfig(batch=4, seq_len=32, vocab_size=128, seed=3)
    a = next(SyntheticLM(c).batches())
    b = next(SyntheticLM(c).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 128
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_stream_has_learnable_structure():
    """The n-gram copy structure must make the stream compressible —
    repeated tokens at the configured period."""
    c = DataConfig(batch=2, seq_len=64, vocab_size=4096, seed=0,
                   ngram_repeat=8)
    b = next(SyntheticLM(c).batches())
    seq = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    hits = sum(int(seq[i, k] == seq[i, k - 8])
               for i in range(2) for k in range(8, 65, 8))
    assert hits >= 14   # nearly all periodic positions repeat


def test_host_shard_partitions_batch():
    c = DataConfig(batch=8, seq_len=16, vocab_size=64, seed=1)
    full = next(SyntheticLM(c).batches())
    parts = [next(SyntheticLM(c).host_shard(h, 4)) for h in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(stacked, full["tokens"])


def test_checkpoint_sharded_write_then_full_restore(tmp_path):
    """Partial proactive replication (§5): two writers each persist half
    the leaves; a restore over the union sees everything."""
    cfg = opt_config("opt-125m").reduced()
    params = P.init_params(cfg, jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 3, {"p": params}, num_shards=2, shard_id=0)
    ckpt.save(str(tmp_path), 3, {"p": params}, num_shards=2, shard_id=1)
    state = ckpt.restore(str(tmp_path), {"p": params})
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(state["p"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_prune_keeps_latest(tmp_path):
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=64,
                                         vocab_size=64)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, {"p": params})
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_partial_write_raises_then_merges(tmp_path):
    """One of two leaf-modulo writers crashed: restore names every
    missing file in ONE error; writing the second shard heals it."""
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=64,
                                         vocab_size=64)
    params = P.init_params(cfg, jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 9, {"p": params}, num_shards=2, shard_id=0)
    with pytest.raises(ckpt.IncompleteCheckpointError) as ei:
        ckpt.restore(str(tmp_path), {"p": params}, step=9)
    msg = str(ei.value)
    assert "incomplete" in msg and "shard 1" in msg and ".npy" in msg
    assert ckpt.latest_complete_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 9, {"p": params}, num_shards=2, shard_id=1)
    assert ckpt.latest_complete_step(str(tmp_path)) == 9
    state = ckpt.restore(str(tmp_path), {"p": params})
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(state["p"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_bf16_roundtrip_bitexact(tmp_path):
    """bf16 leaves persist via the uint16 bit-pattern view and restore
    bit-identically with the bf16 dtype (no float casting detour)."""
    tree = {"w": jnp.arange(37, dtype=jnp.float32).astype(jnp.bfloat16)
            * jnp.bfloat16(0.1),
            "b": jnp.ones((3, 5), jnp.bfloat16),
            "f32": jnp.linspace(0, 1, 11, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    back = ckpt.restore(str(tmp_path), tree)
    for k in tree:
        assert back[k].dtype == tree[k].dtype, k
        a = np.asarray(tree[k])
        b = np.asarray(back[k])
        if a.dtype.kind == "V":
            a, b = a.view(np.uint16), b.view(np.uint16)
        np.testing.assert_array_equal(a, b)


def test_prune_is_shard_aware(tmp_path):
    """Incomplete steps never count toward keep; the newest COMPLETE step
    survives; a newer in-flight (incomplete) write is left alone; dead
    older partial writes are removed."""
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=64,
                                         vocab_size=64)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    tree = {"p": params}
    ckpt.save(str(tmp_path), 1, tree)                       # complete
    ckpt.save(str(tmp_path), 2, tree, num_shards=2, shard_id=0)  # dead
    ckpt.save(str(tmp_path), 3, tree)                       # complete
    ckpt.save(str(tmp_path), 4, tree, num_shards=2, shard_id=1)  # inflight
    ckpt.prune(str(tmp_path), keep=1)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]          # 3 = newest complete, 4 = in-flight
    assert ckpt.latest_complete_step(str(tmp_path)) == 3
    # restore with no explicit step skips the incomplete newest
    state = ckpt.restore(str(tmp_path), tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_restore_rejects_mismatched_tree(tmp_path):
    cfg = opt_config("opt-125m").reduced(num_layers=2, d_model=64,
                                         vocab_size=64)
    params = P.init_params(cfg, jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 1, {"p": params})
    with pytest.raises(ValueError, match="does not match"):
        ckpt.restore(str(tmp_path), {"other": params})
