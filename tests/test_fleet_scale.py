"""Fleet-scale vectorization: bit-exactness against the scalar references.

The massive-fleet engine only earns its speedups if the array paths are
*exactly* the scalar paths — every float identical, every draw identical
— so experiments at 10² devices (where the scalar code runs) transfer
verbatim to 10⁵ (where it can't).  These tests pin that contract
property-style across random topologies, group shapes, and seeds:

* keyed RNG lanes ≡ ``np.random.default_rng([...])`` per entity, with
  ``random()`` / bounded ``integers()`` draws freely interleaved,
* batched fault draws ≡ the per-entity stateless draws (PR-7 contract),
* batched collective kernels ≡ the dict-topology cost models (all five
  algorithms, per-group totals AND per-member busy/bytes),
* ``price_fleet_grid`` ≡ ``dtfm.plan_placement`` on the equivalent spec,
* FleetSim's vectorized engine ≡ its per-entity scalar engine (whole
  churn trajectories),

plus the satellite guarantees: the hierarchical search never prices
worse than round-robin, the scalar search memoizes duplicate candidate
grids (``candidates_pruned``), and the topology's region index stays
consistent under mutation.  Hypothesis drives the sweeps where
installed; containers without it run seeded sweeps over the same
parameter space instead of skipping.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888
from repro.core.faultinject.keyed import keyed_streams
from repro.core.faultinject.plan import FaultPlan
from repro.core.net import NetParams, Topology
from repro.core.net.collectives import (batched_collective_cost,
                                        batched_sync_cost,
                                        collective_cost, sync_cost)
from repro.core.net.fleet_arrays import FleetArrays, synthetic_fleet
from repro.core.placement import (price_fleet_grid, search_placement,
                                  search_placement_fleet)
from repro.core.planner import dtfm
from repro.core.sched.fleet_sim import FleetSim, FleetSimConfig

CFG = get_config("opt-125m")
ALGORITHMS = ("ring", "tree", "hierarchical", "gossip", "allgather")
REGIONS = ("europe", "north_america", "east_asia", "nordics")


# --------------------------------------------------------------------------- #
# Keyed RNG lanes vs np.random.default_rng
# --------------------------------------------------------------------------- #

def _exercise_keyed(seed: int, lanes: int = 11, draws: int = 10):
    rng = np.random.RandomState(seed)
    ncols = int(rng.randint(2, 6))
    cols = [rng.randint(0, 2 ** 31, size=lanes).astype(np.uint32)
            for _ in range(ncols)]
    s = keyed_streams(cols)
    refs = [np.random.default_rng([int(c[i]) for c in cols])
            for i in range(lanes)]
    for _ in range(draws):
        if rng.randint(2) == 0:
            got = s.random()
            want = np.array([r.random() for r in refs])
        else:
            lo = int(rng.randint(-3, 4))
            hi = lo + int(rng.randint(1, 60))
            got = s.integers(lo, hi)
            want = np.array([int(r.integers(lo, hi)) for r in refs])
        assert np.array_equal(got, want)


@pytest.mark.parametrize("seed", range(4))
def test_keyed_streams_match_default_rng(seed):
    _exercise_keyed(seed)


def test_keyed_streams_hypothesis():
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(99)
        for _ in range(15):
            _exercise_keyed(int(rng.randint(0, 2 ** 16)),
                            lanes=int(rng.randint(1, 33)))
        return

    @hyp.given(seed=st.integers(0, 2 ** 16), lanes=st.integers(1, 32))
    @hyp.settings(max_examples=15, deadline=None)
    def prop(seed, lanes):
        _exercise_keyed(seed, lanes=lanes)

    prop()


# --------------------------------------------------------------------------- #
# Batched fault draws vs per-entity stateless draws
# --------------------------------------------------------------------------- #

def _exercise_fault_draws(seed: int):
    rng = np.random.RandomState(seed)
    plan = FaultPlan(seed=int(rng.randint(0, 1000)),
                     straggler_frac=float(rng.uniform(0.05, 0.5)),
                     crash_prob=float(rng.uniform(0.01, 0.3)),
                     rejoin_delay=(1, int(rng.randint(2, 8))),
                     link_flap_prob=float(rng.uniform(0.01, 0.4)),
                     corrupt_prob=float(rng.uniform(0.05, 0.5)))
    n = int(rng.randint(5, 60))
    # mixed entity kinds in one batch — ints and node-name strings
    ents = [int(i) for i in range(n // 2)] \
        + [f"node:{i}" for i in range(n - n // 2)]
    t = int(rng.randint(0, 50))
    assert np.array_equal(plan.slowdown_batch(ents),
                          [plan.slowdown(e) for e in ents])
    assert np.array_equal(plan.crashes_batch(ents, t),
                          [plan.crashes(e, t) for e in ents])
    assert np.array_equal(plan.flaps_batch(ents, t),
                          [plan.flaps(e, t) for e in ents])
    assert np.array_equal(plan.jitter_batch(ents, t),
                          [plan.jitter_s(e, t) for e in ents])
    assert np.array_equal(plan.rejoin_after_batch(ents, t),
                          [plan.rejoin_after(e, t) for e in ents])
    shards = [int(x) for x in rng.randint(0, 30, size=n)]
    holders = [f"h{int(x)}" for x in rng.randint(0, 5, size=n)]
    assert np.array_equal(
        plan.corrupts_batch(t, shards, holders),
        [plan.corrupts(t, s, h) for s, h in zip(shards, holders)])


@pytest.mark.parametrize("seed", range(4))
def test_fault_draws_batch_scalar_parity(seed):
    _exercise_fault_draws(seed)


def test_fault_draws_hypothesis():
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(7)
        for _ in range(10):
            _exercise_fault_draws(int(rng.randint(0, 2 ** 16)))
        return

    @hyp.given(seed=st.integers(0, 2 ** 16))
    @hyp.settings(max_examples=10, deadline=None)
    def prop(seed):
        _exercise_fault_draws(seed)

    prop()


# --------------------------------------------------------------------------- #
# Batched collective kernels vs scalar cost models
# --------------------------------------------------------------------------- #

def _random_fleet(rng, n_lo=6, n_hi=36) -> FleetArrays:
    n = int(rng.randint(n_lo, n_hi))
    k = int(rng.randint(1, 5))
    return synthetic_fleet(
        n, regions=REGIONS[:k],
        region_mix="shuffled" if rng.randint(2) else "round_robin",
        params=NetParams(wan_bw_Bps=float(rng.choice([5e6, 2e7, 1e8]))),
        seed=int(rng.randint(0, 1000)))


def _exercise_collectives(seed: int):
    rng = np.random.RandomState(seed)
    fleet = _random_fleet(rng)
    topo = fleet.to_topology()
    member_dev, member_grp, groups = [], [], []
    for g in range(int(rng.randint(1, 6))):
        size = int(rng.randint(1, min(12, fleet.num_devices) + 1))
        rows = rng.choice(fleet.num_devices, size=size, replace=False)
        groups.append([int(r) for r in rows])        # caller order kept
        member_dev.extend(int(r) for r in rows)
        member_grp.extend([g] * size)
    nbytes = float(rng.choice([1e6, 5e7, 2e9]))
    for algo in ALGORITHMS:
        b = batched_collective_cost(fleet, np.asarray(member_dev),
                                    np.asarray(member_grp), nbytes,
                                    algorithm=algo)
        for g, rows in enumerate(groups):
            nodes = [str(fleet.node_names[r]) for r in rows]
            s = collective_cost(topo, nodes, nbytes, algorithm=algo)
            i = b.group(g)
            assert b.time_s[i] == s.time_s, (algo, g)
            assert b.wire_bytes[i] == s.wire_bytes, (algo, g)
            assert b.wan_bytes[i] == s.wan_bytes, (algo, g)
            assert int(b.participants[i]) == s.participants
            sel = b.member_group == g
            for d, busy, byts in zip(b.member_device[sel], b.busy_s[sel],
                                     b.bytes_dev[sel]):
                name = str(fleet.node_names[int(d)])
                assert busy == s.per_device_busy_s[name], (algo, g, name)
                assert byts == s.per_device_bytes[name], (algo, g, name)


@pytest.mark.parametrize("seed", range(5))
def test_batched_collectives_match_scalar(seed):
    _exercise_collectives(seed)


def test_batched_collectives_hypothesis():
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(42)
        for _ in range(10):
            _exercise_collectives(int(rng.randint(0, 2 ** 16)))
        return

    @hyp.given(seed=st.integers(0, 2 ** 16))
    @hyp.settings(max_examples=10, deadline=None)
    def prop(seed):
        _exercise_collectives(seed)

    prop()


def test_batched_sync_cost_matches_scalar():
    rng = np.random.RandomState(0)
    fleet = _random_fleet(rng, n_lo=12, n_hi=13)
    topo = fleet.to_topology()
    dev = np.arange(12)
    grp = np.repeat(np.arange(3), 4)
    for k in (1, 4):
        b = batched_sync_cost(fleet, dev, grp, 10_000_000,
                              algorithm="hierarchical", dtype_bytes=2,
                              sync_interval=k)
        for g in range(3):
            nodes = [str(fleet.node_names[r]) for r in dev[grp == g]]
            s = sync_cost(topo, nodes, 10_000_000,
                          algorithm="hierarchical", dtype_bytes=2,
                          sync_interval=k)
            i = b.group(g)
            assert b.time_s[i] == s.time_s
            assert b.wire_bytes[i] == s.wire_bytes
            assert b.wan_bytes[i] == s.wan_bytes


# --------------------------------------------------------------------------- #
# Vectorized grid pricing vs dtfm.plan_placement
# --------------------------------------------------------------------------- #

def _exercise_pricing(seed: int):
    rng = np.random.RandomState(seed)
    fleet = _random_fleet(rng, n_lo=12, n_hi=28)
    dp = int(rng.choice([1, 2, 4]))
    S = int(rng.randint(2, 5))
    if dp * S > fleet.num_devices:
        dp, S = 2, 2
    rows = rng.choice(fleet.num_devices, size=dp * S, replace=False)
    grid = rows.reshape(dp, S)
    algo = str(rng.choice(["ring", "hierarchical", "tree"]))
    k = int(rng.choice([1, 2]))
    fp = price_fleet_grid(fleet, CFG, grid, batch=16, seq_len=128,
                          microbatches=4, collective=algo,
                          sync_interval=k)
    spec = fp.to_spec(CFG)
    p = dtfm.plan_placement(CFG, spec, batch=16, seq_len=128,
                            microbatches=4, collective=algo,
                            sync_interval=k)
    assert fp.step_time_s == p.step_time_s
    assert fp.wan_bytes_per_step == p.wan_bytes_per_step
    assert fp.wire_bytes_per_step == p.wire_bytes_per_step
    assert fp.cross_region_edges == spec.cross_region_edges()


@pytest.mark.parametrize("seed", range(4))
def test_price_fleet_grid_matches_plan_placement(seed):
    _exercise_pricing(seed)


def test_price_fleet_grid_hypothesis():
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(17)
        for _ in range(8):
            _exercise_pricing(int(rng.randint(0, 2 ** 16)))
        return

    @hyp.given(seed=st.integers(0, 2 ** 16))
    @hyp.settings(max_examples=8, deadline=None)
    def prop(seed):
        _exercise_pricing(seed)

    prop()


# --------------------------------------------------------------------------- #
# Hierarchical fleet search: soundness + provenance
# --------------------------------------------------------------------------- #

def test_fleet_search_never_worse_than_round_robin():
    fleet = synthetic_fleet(64, region_mix="shuffled",
                            params=NetParams(wan_bw_Bps=5e6), seed=2)
    best = search_placement_fleet(fleet, CFG, data_parallel=4,
                                  batch=16, seq_len=128, microbatches=4)
    stats = best.search_stats
    assert best.step_time_s <= stats["round_robin_step_time_s"]
    assert stats["candidates_pruned"] >= 0
    assert stats["candidates_priced"] <= stats["candidates_total"]
    # the winner reprices identically through the scalar model
    spec = best.to_spec(CFG)
    p = dtfm.plan_placement(CFG, spec, batch=16, seq_len=128,
                            microbatches=4, collective="hierarchical")
    assert p.step_time_s == best.step_time_s
    assert p.wan_bytes_per_step == best.wan_bytes_per_step
    assert spec.search_stats == stats       # provenance rides the spec


def test_scalar_search_memoizes_duplicate_grids():
    """A uniform single-region fleet makes every candidate ordering
    carve into the same grid — the memo must collapse them and report
    the collapse in ``candidates_pruned``."""
    devices = [LAPTOP_M2PRO] * 4
    topo = Topology.from_specs(devices)
    nodes = [str(i) for i in range(4)]
    spec = search_placement(CFG, devices, topology=topo, nodes=nodes,
                            data_parallel=2, batch=8, seq_len=64,
                            microbatches=2)
    stats = spec.search_stats
    assert stats["candidates_total"] > stats["candidates_priced"]
    assert stats["candidates_pruned"] > 0
    assert stats["candidates_pruned"] == (stats["candidates_total"]
                                          - stats["candidates_priced"])


def test_heterogeneous_search_still_reports_stats():
    devices = [LAPTOP_M2PRO, SMARTPHONE_SD888] * 2
    topo = Topology.from_specs(devices,
                               regions=["europe", "north_america"])
    nodes = [str(i) for i in range(4)]
    spec = search_placement(CFG, devices, topology=topo, nodes=nodes,
                            data_parallel=2, batch=8, seq_len=64,
                            microbatches=2)
    assert spec.search_stats["candidates_total"] >= 2
    assert "search_wall_s" in spec.search_stats


# --------------------------------------------------------------------------- #
# FleetSim: scalar engine ≡ vectorized engine
# --------------------------------------------------------------------------- #

def _exercise_sim(seed: int, n: int = 200, rounds: int = 8):
    rng = np.random.RandomState(seed)
    plan = FaultPlan(seed=int(rng.randint(0, 100)),
                     straggler_frac=0.15, crash_prob=0.02,
                     rejoin_delay=(1, 4), link_flap_prob=0.1)
    cfg = FleetSimConfig(
        rounds=rounds, seed=int(rng.randint(0, 100)),
        leave_prob=float(rng.uniform(0, 0.05)),
        join_prob=float(rng.uniform(0, 0.5)),
        mode="async" if rng.randint(2) else "sync",
        quorum=float(rng.uniform(0.5, 1.0)), fault_plan=plan)
    fleet = synthetic_fleet(n, region_mix="shuffled",
                            seed=int(rng.randint(0, 100)))
    sim = FleetSim(fleet, cfg)
    rv = sim.run("vectorized")
    rs = sim.run("scalar")
    assert rv.trajectory_equal(rs)
    assert rv.region_busy_s == rs.region_busy_s
    assert rv.wall_time_s == rs.wall_time_s
    assert rv.rounds == rounds and (rv.active_counts > 0).all()


@pytest.mark.parametrize("seed", range(3))
def test_fleet_sim_engines_bit_identical(seed):
    _exercise_sim(seed)


def test_fleet_sim_hypothesis():
    try:
        import hypothesis as hyp
        from hypothesis import strategies as st
    except ImportError:
        rng = np.random.RandomState(5)
        for _ in range(6):
            _exercise_sim(int(rng.randint(0, 2 ** 16)),
                          n=int(rng.randint(20, 300)))
        return

    @hyp.given(seed=st.integers(0, 2 ** 16), n=st.integers(20, 300))
    @hyp.settings(max_examples=6, deadline=None)
    def prop(seed, n):
        _exercise_sim(seed, n=n)

    prop()


def test_fleet_sim_async_quorum_cuts_straggler_tail():
    plan = FaultPlan(seed=3, straggler_frac=0.2,
                     straggler_slowdown=(4.0, 8.0))
    fleet = synthetic_fleet(500, seed=1)
    base = dict(rounds=10, seed=4, fault_plan=plan)
    sync = FleetSim(fleet, FleetSimConfig(mode="sync", **base)).run()
    asyn = FleetSim(fleet, FleetSimConfig(mode="async", quorum=0.75,
                                          **base)).run()
    assert asyn.wall_time_s < sync.wall_time_s


# --------------------------------------------------------------------------- #
# Topology region index + FleetArrays round-trip
# --------------------------------------------------------------------------- #

def test_topology_region_index_tracks_mutation():
    topo = Topology()
    topo.add_device("a", "europe", LAPTOP_M2PRO)
    topo.add_device("b", "europe", SMARTPHONE_SD888)
    topo.add_device("c", "asia", LAPTOP_M2PRO)
    assert topo.regions == ["europe", "asia"]
    assert topo.devices_in_region("europe") == ["a", "b"]
    topo.add_device("b", "asia", SMARTPHONE_SD888)   # region move
    assert topo.devices_in_region("europe") == ["a"]
    assert sorted(topo.devices_in_region("asia")) == ["b", "c"]
    # the index is exactly the inverse of device_region
    for r in topo.regions:
        for d in topo.devices_in_region(r):
            assert topo.device_region[d] == r


def test_fleet_arrays_topology_round_trip():
    fleet = synthetic_fleet(30, region_mix="shuffled", seed=9)
    back = FleetArrays.from_topology(fleet.to_topology())
    assert np.array_equal(fleet.node_names, back.node_names)
    assert np.array_equal(fleet.region_of, back.region_of)
    assert np.array_equal(fleet.eff_flops, back.eff_flops)
    assert np.array_equal(fleet.acc_bw, back.acc_bw)
    assert np.array_equal(fleet.wan_bw, back.wan_bw)
