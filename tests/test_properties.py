"""Property-based tests (hypothesis) on the system's invariants.

Targets the paper-contribution layers: carbon accounting, the idealized /
DT-FM planners, carbon-aware scheduling, fault-tolerance Pareto logic,
gradient compression, and the analytic FLOP model.
"""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.opt import opt_config
from repro.core import flops as F
from repro.core.carbon.accounting import CarbonLedger
from repro.core.carbon.intensity import INTENSITY_BY_REGION, IntensityTrace
from repro.core.energy.devices import (CATALOG, CLOUD_H100, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888)
from repro.core.planner import dtfm, idealized
from repro.core.sched.carbon_aware import (FleetDevice, fleet_carbon_rate,
                                           select_fleet)
from repro.core.sched.faults import FaultModel, pareto_frontier

DEVICES = st.sampled_from(list(CATALOG.values()))
SMALL_OPT = st.sampled_from(["opt-125m", "opt-1.3b", "opt-6.7b"])


# --------------------------------------------------------------------- carbon
@given(st.lists(st.tuples(st.floats(0, 1e4), st.floats(0, 1e4)),
                min_size=1, max_size=20))
def test_ledger_totals_are_sums(entries):
    led = CarbonLedger(intensity_kg_per_kwh=0.3)
    for i, (kwh, emb) in enumerate(entries):
        led.add_operational_kwh(f"op{i}", kwh)
        e = led.entries[-1]
        assert e.operational_kg == pytest.approx(kwh * 0.3)
    assert led.total_kg == pytest.approx(
        sum(k * 0.3 for k, _ in entries))
    assert led.operational_kg >= 0 and led.embodied_kg == 0


@given(st.sampled_from(sorted(INTENSITY_BY_REGION)),
       st.floats(0, 24), st.floats(-12, 12))
def test_intensity_trace_bounded_by_base(region, hour, tz):
    tr = IntensityTrace(region=region, year=2023)
    base = INTENSITY_BY_REGION[region][2023]
    v = tr.at_hour(hour, tz)
    assert 0 < v <= base + 1e-12
    assert tr.daily_mean(tz) <= base


# ------------------------------------------------------------------- planners
@given(SMALL_OPT, DEVICES, st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_idealized_energy_monotone_in_devices(name, dev, n):
    """More devices never increase per-device compute time; total energy is
    compute-dominated and stays within 2x of the single-sum lower bound."""
    cfg = opt_config(name)
    p1 = idealized.plan(cfg, dev, batch=16, seq_len=512, steps=10,
                        num_devices=n)
    p2 = idealized.plan(cfg, dev, batch=16, seq_len=512, steps=10,
                        num_devices=2 * n)
    assert p2.compute_s <= p1.compute_s * (1 + 1e-9)
    # fleet compute energy is invariant to the split (perfect divisibility)
    assert p2.energy_wh == pytest.approx(p1.energy_wh, rel=1e-6)


@given(SMALL_OPT, st.integers(1, 12), st.integers(1, 6))
@settings(max_examples=40, deadline=None)
def test_dtfm_plan_invariants(name, n_laptops, n_phones):
    cfg = opt_config(name)
    fleet = [LAPTOP_M2PRO] * n_laptops + [SMARTPHONE_SD888] * n_phones
    plan = dtfm.plan(cfg, fleet, batch=16, seq_len=512, microbatches=8)
    # stage partition covers all layers exactly once, contiguously
    covered = []
    for s in plan.stages:
        covered.extend(list(s.layers))
    assert covered == list(range(cfg.num_layers))
    # bubble fraction in [0, 1); makespan at least the compute lower bound
    assert 0 <= plan.bubble_fraction < 1
    slowest = max(s.time_per_microbatch_s for s in plan.stages)
    assert plan.step_time_s >= plan.microbatches * slowest - 1e-9
    assert plan.total_energy_wh_per_step > 0


@given(st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_dtfm_heterogeneous_balances_by_speed(n):
    """Faster devices get at least as many layers as slower ones."""
    cfg = opt_config("opt-1.3b")
    fleet = [LAPTOP_M2PRO, SMARTPHONE_SD888] * n
    splits = dtfm.partition_layers(cfg, fleet)
    lap = sum(len(splits[i]) for i in range(0, 2 * n, 2))
    pho = sum(len(splits[i]) for i in range(1, 2 * n, 2))
    assert lap >= pho


# ------------------------------------------------------------------ scheduler
@given(st.integers(2, 30), st.floats(1, 23))
@settings(max_examples=30, deadline=None)
def test_select_fleet_is_greedy_optimal_rate(n, hour):
    fleet = [FleetDevice(spec=LAPTOP_M2PRO,
                         region=["nordics", "india"][i % 2], device_id=i)
             for i in range(n)]
    target = (n // 2) * LAPTOP_M2PRO.effective_flops * 0.5
    sel = select_fleet(fleet, target_flops=target, hour_utc=hour)
    assert sum(s.effective_flops for s in sel) >= target
    # greedy: selection rate <= rate of any same-size alternative subset
    rate = fleet_carbon_rate(sel)
    all_priced = select_fleet(fleet, target_flops=float("inf"),
                              hour_utc=hour)
    worst = fleet_carbon_rate(all_priced[-len(sel):])
    assert rate <= worst + 1e-12


@given(st.floats(0.01, 2.0), st.integers(2, 64), st.floats(5, 120))
@settings(max_examples=30, deadline=None)
def test_pareto_frontier_is_nondominated(lam, n, step):
    fm = FaultModel(lambda_per_device_hour=lam, num_devices=n,
                    step_time_s=step, ckpt_write_s=20.0,
                    ckpt_restore_s=30.0, stage_recompute_s=4 * step)
    frontier = pareto_frontier(fm)
    assert frontier
    for a in frontier:
        assert a.slowdown >= 1.0 and a.energy_overhead >= 0.0
        for b in frontier:
            if a is not b:
                assert not a.dominates(b)


# ---------------------------------------------------------------- flops model
@given(SMALL_OPT, st.integers(1, 32), st.sampled_from([128, 512, 2048]))
@settings(max_examples=40, deadline=None)
def test_flops_model_scaling_laws(name, batch, seq):
    cfg = opt_config(name)
    f1 = F.fwd_flops(cfg, batch, seq)
    f2 = F.fwd_flops(cfg, 2 * batch, seq)
    assert f2 == pytest.approx(2 * f1, rel=1e-9)          # linear in batch
    t = F.train_flops(cfg, batch, seq, remat=False)
    tr = F.train_flops(cfg, batch, seq, remat=True)
    assert t == pytest.approx(3 * f1, rel=1e-9)           # fwd + 2x bwd
    assert tr == pytest.approx(4 * f1, rel=1e-9)          # + recompute
    # decode flops for 1 token << prefill flops for the same cache
    assert F.decode_flops(cfg, batch, seq) < f1


@given(SMALL_OPT, st.integers(1, 8), st.sampled_from([256, 1024]))
@settings(max_examples=20, deadline=None)
def test_kv_cache_monotone(name, batch, seq):
    cfg = opt_config(name)
    b1 = F.kv_cache_bytes(cfg, batch, seq)
    assert F.kv_cache_bytes(cfg, batch, 2 * seq) == pytest.approx(2 * b1)
    assert F.kv_cache_bytes(cfg, 2 * batch, seq) == pytest.approx(2 * b1)


# ------------------------------------------------------------- compression
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 256, 1000]),
       st.sampled_from(["int8", "topk"]))
@settings(max_examples=30, deadline=None)
def test_compression_error_feedback_contract(seed, n, method):
    """Compressed grad + residual must reconstruct the original exactly
    (error feedback keeps the lossy part, nothing vanishes)."""
    import jax
    import jax.numpy as jnp
    from repro.optim.compress import CompressConfig, compress_grads
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(n,)).astype(np.float32))}
    cfgc = CompressConfig(method=method, topk_fraction=0.25)
    sent, new_err = compress_grads(g, None, cfgc)
    recon = np.asarray(sent["w"], np.float32) + np.asarray(new_err["w"])
    np.testing.assert_allclose(recon, np.asarray(g["w"]), rtol=1e-5,
                               atol=1e-6)
