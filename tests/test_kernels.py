"""Per-kernel validation: Pallas (interpret mode) and the XLA chunked twin
swept over shapes/dtypes against the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.chunked import chunked_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.quant8.kernel import dequantize_blocks, quantize_blocks
from repro.kernels.quant8.ops import dequantize, quantize
from repro.kernels.quant8.ref import dequantize_reference, quantize_reference
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference, ssd_step

FA_CASES = [
    # B, S, H, K, D, causal, window
    (2, 256, 4, 2, 64, True, 0),
    (1, 384, 4, 4, 128, True, 0),
    (2, 256, 8, 2, 64, True, 128),
    (1, 200, 2, 1, 64, False, 0),
    (1, 130, 2, 2, 96, True, 0),
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, S, H, K, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_pallas_grad_vs_chunked(case):
    """Fused Pallas FA-2 backward (interpret) == chunked custom-VJP grads
    to <=1e-3 in fp32 across causal / sliding-window / GQA / padded-seq
    (acceptance criterion for the pallas training path)."""
    B, S, H, K, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    w = jax.random.normal(ks[3], (B, S, H, D))   # non-trivial cotangent

    fp = lambda *a: (flash_attention(*a, causal=causal, window=window,  # noqa
                                     interpret=True) * w).sum()
    fc = lambda *a: (chunked_attention(*a, causal=causal, window=window,  # noqa
                                       chunk=64) * w).sum()
    gp = jax.grad(fp, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(fc, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), gp, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3, err_msg=name)


def test_flash_attention_pallas_grad_bf16_runs():
    """bf16 primals: backward runs and cotangents keep the primal dtype
    (custom_vjp contract)."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.bfloat16)
    f = lambda *a: flash_attention(*a, causal=True,                     # noqa
                                   interpret=True).astype(jnp.float32).sum()
    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    assert gq.dtype == jnp.bfloat16 and gk.dtype == jnp.bfloat16 \
        and gv.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(gq, np.float32)).all()


def test_train_step_accepts_donated_buffers():
    """The jitted train step runs with donate_argnums=(params, opt_state):
    two consecutive steps reuse the chain of donated buffers without error
    and keep producing finite losses."""
    from repro.configs.opt import opt_config
    from repro.optim import adamw
    from repro.train.step import make_train_step

    cfg = opt_config("opt-125m").reduced(num_layers=1, d_model=64,
                                         vocab_size=256)
    from repro.models import params as PM
    params = PM.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.OptConfig(warmup_steps=1, decay_steps=4)
    opt_state = adamw.init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    for _ in range(2):
        params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("case", FA_CASES)
def test_chunked_attention_fwd_and_grad(case):
    B, S, H, K, D, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))

    out = chunked_attention(q, k, v, causal=causal, window=window, chunk=64)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    f = lambda *a: chunked_attention(*a, causal=causal, window=window,  # noqa
                                     chunk=64).sum()
    g = lambda *a: attention_reference(*a, causal=causal,               # noqa
                                       window=window).astype(jnp.float32).sum()
    gc = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


SSD_CASES = [
    # b, s, h, g, p, n, chunk
    (2, 256, 4, 1, 64, 32, 64),
    (1, 128, 8, 2, 32, 128, 32),
    (2, 100, 4, 4, 64, 16, 32),
    (1, 512, 2, 1, 128, 64, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_vs_ref(case, dtype):
    b, s, h, g, p, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n), dtype)
    C = jax.random.normal(ks[4], (b, s, g, n), dtype)
    out = ssd(x, dt, A, B, C, chunk, interpret=True)
    ref = ssd_reference(x, dt, A, B, C, chunk)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(ref, np.float32) / scale,
                               rtol=tol, atol=tol)


def test_ssd_step_matches_full_scan():
    """Sequential single-step recurrence == chunked full-sequence scan."""
    b, s, h, g, p, n = 1, 32, 2, 1, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    full = ssd_reference(x, dt, A, B, C, chunk_size=8)
    state = jnp.zeros((b, h, p, n))
    outs = []
    for t in range(s):
        y, state = ssd_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        outs.append(y)
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,block", [(65536, 256), (512 * 512, 512),
                                     (1 << 16, 128)])
def test_quant8_kernel_vs_ref(n, block):
    x = jax.random.normal(jax.random.PRNGKey(4), (n,)) * 3
    qk, sk = quantize_blocks(x, block=block, interpret=True)
    qr, sr = quantize_reference(x, block)
    assert bool(jnp.all(qk == qr))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    dk = dequantize_blocks(qk, sk, block=block, interpret=True)
    dr = dequantize_reference(qr, sr, block)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6)


@pytest.mark.parametrize("shape", [(100, 777), (3, 5, 7), (65536,)])
def test_quant8_roundtrip_error_bound(shape):
    x = jax.random.normal(jax.random.PRNGKey(5), shape) * 2
    q, s, sh = quantize(x)
    xr = dequantize(q, s, sh)
    # blockwise bound: |err| <= scale/2 per block
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-flat.shape[0]) % 256
    fb = np.pad(flat, (0, pad)).reshape(-1, 256)
    bound = np.repeat(np.abs(fb).max(1) / 127 * 0.5 + 1e-6,
                      256)[:flat.shape[0]]
    err = np.abs(np.asarray(xr, np.float32).reshape(-1) - flat)
    assert np.all(err <= bound)
