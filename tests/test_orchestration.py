"""Orchestration-layer tests: simulator determinism, thermal model,
carbon-aware admission, checkpoint round-trip."""

import numpy as np
import pytest

from repro.configs.opt import opt_config
from repro.core.sched.carbon_aware import FleetDevice, carbon_rate
from repro.core.sched.orchestrator import Orchestrator, SimConfig, make_fleet
from repro.core.sched.thermal import (LAPTOP_THERMALS, PHONE_THERMALS,
                                      ThermalState, sustained_perf)


def test_simulator_deterministic():
    cfg = opt_config("opt-125m")
    fleet = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    a = Orchestrator(cfg, fleet, SimConfig(total_steps=40, seed=5)).run()
    fleet2 = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6}, seed=2)
    b = Orchestrator(cfg, fleet2, SimConfig(total_steps=40, seed=5)).run()
    assert a.wall_time_s == b.wall_time_s
    assert a.energy_wh == b.energy_wh
    assert a.membership_changes == b.membership_changes


def test_simulator_completes_requested_steps():
    cfg = opt_config("opt-125m")
    fleet = make_fleet({"laptop-m2pro": 4}, seed=0)
    res = Orchestrator(cfg, fleet, SimConfig(total_steps=30, seed=0)).run()
    assert res.steps_done == 30
    assert res.wall_time_s > 0 and res.energy_wh > 0
    assert 1 <= res.mean_active_devices <= 4 + 1e-9


def test_thermal_throttling_derates_under_load():
    st = ThermalState(PHONE_THERMALS)
    cold = st.perf_factor()
    for _ in range(600):
        st.step(10.0, 1.0)          # 10 W for 10 minutes
    hot = st.perf_factor()
    assert cold == pytest.approx(1.0, abs=1e-6)
    assert hot < cold
    # laptops sustain more power before throttling
    assert sustained_perf(LAPTOP_THERMALS, 15.0) >= \
        sustained_perf(PHONE_THERMALS, 15.0)


def test_carbon_rate_orders_clean_grids_first():
    a = FleetDevice(spec=make_fleet({"laptop-m2pro": 1})[0].spec,
                    region="nordics", device_id=0)
    b = FleetDevice(spec=a.spec, region="india", device_id=1)
    ra, _ = carbon_rate(a, 12.0, {})
    rb, _ = carbon_rate(b, 12.0, {})
    assert ra < rb


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import ckpt
    from repro.models import params as P
    cfg = opt_config("opt-125m").reduced()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 7, {"params": params})
    assert ckpt.latest_step(str(tmp_path)) == 7
    state = ckpt.restore(str(tmp_path), {"params": params})
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(state["params"])
    assert len(flat_a) == len(flat_b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
