"""Placement-layer tests: the plan→place→execute contract.

Pins the tentpole invariants:

* a ``PlacementSpec``'s analytic stage boundaries are exactly the layer
  slices the shard_map pipeline executes (stack/mask/unstack parity),
* a **non-uniform** pipelined model (3 stages over 8 layers) produces
  the same loss AND the same gradients as the unpipelined reference to
  fp32 tolerance — the padded scan slots are provably inert,
* topology-aware placement search never prices worse than round-robin
  on the same fleet (hypothesis property; round-robin is always in the
  candidate set),
* local-SGD maps replicas onto the placement's region groups.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.opt import opt_config
from repro.core.energy.devices import (CATALOG, CLOUD_A5000, LAPTOP_M2PRO,
                                       SMARTPHONE_SD888)
from repro.core.net import NetParams, Topology
from repro.core.placement import (PlacementSpec, StagePlacement,
                                  balanced_boundaries, ordered_placement,
                                  round_robin_placement, search_placement)
from repro.core.planner import dtfm
from repro.core.sched.carbon_aware import FleetDevice
from repro.distributed.pipeline import (make_pipeline_loss, stack_for_stages,
                                        stage_layer_mask, unstack_stages)
from repro.models import model as M
from repro.models import params as P


def fleet(n, regions=("europe", "north_america"), specs=(LAPTOP_M2PRO,)):
    return [FleetDevice(spec=specs[i % len(specs)],
                        region=regions[i % len(regions)], device_id=i)
            for i in range(n)]


def _cfg8():
    cfg = dataclasses.replace(
        opt_config("opt-125m"), name="opt-place-test", num_layers=8,
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
        vocab_size=256)
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


# ----------------------------------------------------------------- spec shape
def test_spec_validates_contiguity_and_boundary_alignment():
    topo = Topology.from_fleet(fleet(4))
    cfg = opt_config("opt-125m")
    spec = search_placement(cfg, [LAPTOP_M2PRO] * 4,
                            topology=topo, nodes=list("0123"),
                            data_parallel=2, batch=8, seq_len=64)
    assert spec.data_parallel == 2 and spec.num_stages == 2
    assert spec.boundaries[0] == 0 and spec.boundaries[-1] == cfg.num_layers
    # a replica with shifted boundaries must be rejected
    bad = PlacementSpec(
        cfg.name, cfg.num_layers,
        [spec.pipelines[0],
         [StagePlacement(s.device, s.node,
                         range(s.layers.start + 1, s.layers.stop + 1))
          for s in spec.pipelines[1]]],
        topo)
    with pytest.raises(ValueError):
        bad.validate()


def test_balanced_boundaries_nonuniform_and_clamped():
    # 2:1 compute ratio -> laptop stages get more layers
    b = balanced_boundaries(12, [2.0, 1.0, 2.0, 1.0])
    assert b[0] == 0 and b[-1] == 12 and b == sorted(b)
    counts = [y - x for x, y in zip(b[:-1], b[1:])]
    assert counts[0] > counts[1]
    # more slots than layers: empty slots, never phantom layers
    b = balanced_boundaries(3, [1.0] * 10)
    assert b[-1] == 3 and all(y - x >= 0 for x, y in zip(b[:-1], b[1:]))


# ------------------------------------------------- spec == executed pipeline
def test_placement_boundaries_match_executed_stage_slices():
    """The analytic spec's layer slices are exactly what the executor
    stacks, masks, and un-stacks."""
    cfg = _cfg8()
    devs = [LAPTOP_M2PRO, CLOUD_A5000, LAPTOP_M2PRO]
    spec = ordered_placement(cfg, devs)
    counts = spec.layer_counts
    assert sum(counts) == cfg.num_layers and len(counts) == 3
    assert max(counts) > min(counts)          # heterogeneity -> non-uniform

    params = P.init_params(cfg, jax.random.PRNGKey(0))
    staged = stack_for_stages(cfg, params, spec)
    lmax = spec.max_stage_layers
    leaf = staged["s0_attn"]["wq"]
    assert leaf.shape[:2] == (3, lmax)
    mask = stage_layer_mask(cfg, spec)
    assert mask.shape == (3, lmax)
    assert [int(m.sum()) for m in mask] == counts
    # padded slots are zero, real slots match the source layers
    ref = params["decoder"]["g0"]["s0_attn"]["wq"]
    for i, (a, b) in enumerate(zip(spec.boundaries[:-1],
                                   spec.boundaries[1:])):
        np.testing.assert_array_equal(np.asarray(leaf[i, :b - a]),
                                      np.asarray(ref[a:b]))
        assert not np.asarray(leaf[i, b - a:]).any()
    # round-trip
    back = unstack_stages(cfg, staged, spec)
    np.testing.assert_array_equal(np.asarray(back["s0_attn"]["wq"]),
                                  np.asarray(ref))


def test_nonuniform_pipeline_matches_unpipelined_loss_and_grads():
    """3 stages over 8 layers (3|3|2): pipelined loss AND grads equal the
    plain forward to fp32 tolerance — masked padding is inert."""
    cfg = _cfg8()
    boundaries = [0, 3, 6, 8]
    mesh = jax.make_mesh((1, 3), ("data", "stage"))
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def ref_loss(p):
        loss, _ = M.forward_train(p, cfg, batch)
        return loss
    ref, ref_grads = jax.value_and_grad(ref_loss)(params)

    loss_fn = make_pipeline_loss(cfg, mesh, num_microbatches=2,
                                 boundaries=boundaries)
    staged = stack_for_stages(cfg, params, boundaries)

    def pipe_loss(p, st):
        return loss_fn(p, st, batch)

    with compat.set_mesh(mesh):
        pipe, (g_rest, g_staged) = jax.jit(
            jax.value_and_grad(pipe_loss, argnums=(0, 1)))(params, staged)

    np.testing.assert_allclose(float(pipe), float(ref), rtol=1e-5)
    g_decoder = unstack_stages(cfg, g_staged, boundaries)
    flat_ref = dict(jax.tree_util.tree_flatten_with_path(
        ref_grads["decoder"]["g0"])[0])
    flat_pipe = dict(jax.tree_util.tree_flatten_with_path(g_decoder)[0])
    assert flat_ref.keys() == flat_pipe.keys()
    for k in flat_ref:
        np.testing.assert_allclose(np.asarray(flat_pipe[k]),
                                   np.asarray(flat_ref[k]),
                                   rtol=2e-4, atol=1e-5, err_msg=str(k))
    # embed/head grads ride outside the pipelined region
    np.testing.assert_allclose(
        np.asarray(g_rest["embed"]["tok"]),
        np.asarray(ref_grads["embed"]["tok"]), rtol=2e-4, atol=1e-5)


def test_uniform_boundaries_keep_legacy_reshape_path():
    cfg = _cfg8()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    a = stack_for_stages(cfg, params, 4)
    b = stack_for_stages(cfg, params, [0, 2, 4, 6, 8])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError):
        stack_for_stages(cfg, params, 3)      # 8 % 3: needs boundaries


# -------------------------------------------------------------- planner side
def test_search_beats_round_robin_on_two_region_fleet():
    cfg = opt_config("opt-125m")
    # regions alternate per device, kinds per PAIR: the naive round-robin
    # carve-up cannot de-interleave both at once
    fl = [FleetDevice(spec=(LAPTOP_M2PRO, SMARTPHONE_SD888)[(i // 2) % 2],
                      region=("europe", "north_america")[i % 2],
                      device_id=i) for i in range(8)]
    topo = Topology.from_fleet(fl, params=NetParams(wan_bw_Bps=5e6))
    devices = [d.spec for d in fl]
    nodes = [str(d.device_id) for d in fl]
    kw = dict(batch=16, seq_len=512, microbatches=8)
    rr = dtfm.plan_placement(
        cfg, round_robin_placement(cfg, devices, topology=topo,
                                   nodes=nodes, data_parallel=2), **kw)
    ta = dtfm.plan_placement(
        cfg, search_placement(cfg, devices, topology=topo, nodes=nodes,
                              data_parallel=2, **kw), **kw)
    assert ta.step_time_s < rr.step_time_s
    assert ta.wan_bytes_per_step < rr.wan_bytes_per_step
    assert ta.placement.strategy.startswith("topology_aware")


def test_dp_regions_price_sync_without_moving_the_pipeline():
    """Legacy dp_regions semantics: it spreads the GRADIENT-SYNC replicas
    across regions while boundary activations stay priced over the real
    nodes' regions — a multi-region pipeline keeps its WAN boundary hop."""
    cfg = opt_config("opt-125m")
    topo = Topology.from_specs([LAPTOP_M2PRO, SMARTPHONE_SD888],
                               regions=["europe", "north_america"])
    kw = dict(batch=16, seq_len=512, data_parallel=2,
              topology=topo, nodes=["0", "1"],
              collective="hierarchical")
    with_regions = dtfm.plan(cfg, [LAPTOP_M2PRO, SMARTPHONE_SD888],
                             dp_regions=["europe", "north_america"], **kw)
    without = dtfm.plan(cfg, [LAPTOP_M2PRO, SMARTPHONE_SD888], **kw)
    # boundary pricing identical: dp_regions must not relocate pipelines
    assert with_regions.boundary_s_per_step == pytest.approx(
        without.boundary_s_per_step)
    assert with_regions.boundary_s_per_step > topo.p2p_time_s(
        1, "0", "0")                      # and it IS a cross-region hop
    # ... but the sync groups DO span the requested regions
    spec = with_regions.placement
    assert spec.dp_sync_nodes
    sync_regions = {topo_region
                    for g in spec.dp_sync_nodes for n in g
                    for topo_region in [spec.topology.device_region[n]]}
    assert sync_regions == {"europe", "north_america"}
    assert with_regions.dp_sync_s_per_step > without.dp_sync_s_per_step


def test_plan_placement_agrees_with_legacy_plan():
    """plan() is now a placement round-trip: pricing an ordered_placement
    directly must give the identical plan."""
    cfg = opt_config("opt-125m")
    devs = [LAPTOP_M2PRO, SMARTPHONE_SD888, CLOUD_A5000]
    kw = dict(batch=16, seq_len=256, microbatches=4)
    a = dtfm.plan(cfg, devs, **kw)
    b = dtfm.plan_placement(cfg, ordered_placement(cfg, devs), **kw)
    assert a.step_time_s == pytest.approx(b.step_time_s)
    assert a.total_energy_wh_per_step == pytest.approx(
        b.total_energy_wh_per_step)
    assert [s.layers for s in a.stages] == [s.layers for s in b.stages]


# ------------------------------------------------------- hypothesis property
def test_search_never_prices_worse_than_round_robin_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    specs_st = st.lists(st.sampled_from(
        [CATALOG["laptop-m2pro"], CATALOG["smartphone-sd888"],
         CATALOG["cloud-a5000"]]), min_size=2, max_size=8)

    @given(specs_st, st.integers(1, 2), st.integers(1, 3))
    @settings(max_examples=25, deadline=None)
    def prop(device_specs, dp, n_regions):
        if len(device_specs) < dp:
            return
        cfg = opt_config("opt-125m")
        regions = ["europe", "north_america", "asia"][:n_regions]
        fl = [FleetDevice(spec=s, region=regions[i % n_regions],
                          device_id=i)
              for i, s in enumerate(device_specs)]
        topo = Topology.from_fleet(fl)
        devices = [d.spec for d in fl]
        nodes = [str(d.device_id) for d in fl]
        kw = dict(batch=16, seq_len=128, microbatches=4)
        rr = dtfm.plan_placement(
            cfg, round_robin_placement(cfg, devices, topology=topo,
                                       nodes=nodes, data_parallel=dp),
            **kw)
        ta = dtfm.plan_placement(
            cfg, search_placement(cfg, devices, topology=topo, nodes=nodes,
                                  data_parallel=dp, **kw), **kw)
        assert ta.step_time_s <= rr.step_time_s * (1 + 1e-12)

    prop()


# ------------------------------------------------------------- local SGD map
def test_local_sgd_maps_replicas_onto_placement_region_groups():
    from repro.optim import adamw
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig

    cfg = dataclasses.replace(
        opt_config("opt-125m").reduced(num_layers=2, d_model=64,
                                       vocab_size=256),
        param_dtype="float32", compute_dtype="float32")
    fl = fleet(4)
    topo = Topology.from_fleet(fl)
    spec = search_placement(cfg, [d.spec for d in fl], topology=topo,
                            nodes=[str(d.device_id) for d in fl],
                            data_parallel=2, batch=4, seq_len=32,
                            microbatches=2)
    ls = LocalSGDConfig(replicas=2, inner_steps=2)
    tc = TrainerConfig(steps=4, batch=4, seq_len=32, log_every=0, seed=0)
    opt = adamw.OptConfig(learning_rate=1e-3, warmup_steps=2, decay_steps=4)
    res = train_local_sgd(cfg, tc, ls, opt, placement=spec)
    assert len(res.replica_regions) == 2
    assert set(res.replica_regions) <= {"europe", "north_america"}
    assert res.comm_time_s_per_round > 0
    assert res.comm_time_s_per_step == pytest.approx(
        res.comm_time_s_per_round / ls.inner_steps)
    # replica-count mismatch and topology+placement double-spec both raise
    with pytest.raises(ValueError):
        train_local_sgd(cfg, tc, LocalSGDConfig(replicas=3, inner_steps=2),
                        opt, placement=spec)
    with pytest.raises(ValueError):
        train_local_sgd(cfg, tc, ls, opt, placement=spec, topology=topo)


# ------------------------------------------------------------- orchestrator
def test_orchestrator_replans_through_placement_api():
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")
    fl = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 2},
                    regions=("europe", "north_america"), seed=1)
    res = Orchestrator(cfg, fl, SimConfig(total_steps=15, seed=1)).run()
    assert res.steps_done == 15
    assert res.last_placement.startswith("topology_aware")
    assert res.wan_bytes_total >= 0.0
