"""HLO-analysis integration: trip-count-aware FLOP/byte/collective walks
against compiled programs with known analytic counts."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.launch.hlo_analysis import (collective_totals, compute_totals,
                                       loop_trip_counts)


def test_scan_flops_exact():
    """A scanned matmul must count trips x per-iteration dot flops."""
    def f(x, ws):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h
    x = jnp.ones((16, 128))
    ws = jnp.ones((6, 128, 128))
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    out = compute_totals(hlo)
    assert out["flops"] == pytest.approx(6 * 2 * 16 * 128 * 128)
    trips = dict(loop_trip_counts(hlo))
    assert 6 in trips.values()


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(h, _):
            def inner(g, w):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, ws)
            return g, None
        h, _ = jax.lax.scan(outer, x, jnp.arange(3))
        return h
    x = jnp.ones((8, 64))
    ws = jnp.ones((4, 64, 64))
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    out = compute_totals(hlo)
    assert out["flops"] == pytest.approx(3 * 4 * 2 * 8 * 64 * 64)


def test_collectives_counted_per_device_with_trips():
    mesh = jax.make_mesh((8,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        def body(h, _):
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P()))          # forces all-gather
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P("data")))
            return h, None
        h, _ = jax.lax.scan(body, x, jnp.arange(5))
        return h.sum()

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    with compat.set_mesh(mesh):
        hlo = jax.jit(
            f, in_shardings=NamedSharding(mesh, P("data"))
        ).lower(x).compile().as_text()
    coll = collective_totals(hlo)
    # at least one all-gather per loop iteration, counted 5x
    ag = coll["counts"].get("all-gather", 0)
    assert ag >= 5, coll
    assert coll["total_bytes"] > 0


def test_train_step_lowers_on_local_mesh_and_parses():
    """End-to-end: the dry-run lowering path on a tiny (2,4) local mesh —
    compile succeeds, the walk returns flops within 3x of 6·N·D, and
    collectives are present (FSDP/TP is active)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.distributed import sharding as SH
    from repro.models import params as PM
    from repro.optim import adamw
    from repro.train.step import make_train_step

    cfg = get_config("granite-3-2b").reduced(num_layers=2, d_model=256,
                                             vocab_size=512)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p_abs = PM.abstract_params(cfg)
    p_shard = SH.param_shardings(cfg, mesh, SH.DEFAULT_RULES)
    opt_cfg = adamw.OptConfig()
    opt_abs = jax.eval_shape(lambda p: adamw.init_opt_state(p, opt_cfg),
                             p_abs)
    opt_shard = {"mu": p_shard, "nu": p_shard,
                 "step": NamedSharding(mesh, P())}
    B, S = 8, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    b_shard = SH.batch_shardings(mesh, batch)
    step = make_train_step(cfg, opt_cfg, remat="full", microbatches=2)
    with compat.set_mesh(mesh):
        compiled = jax.jit(step, in_shardings=(p_shard, opt_shard, b_shard),
                           out_shardings=(p_shard, opt_shard, None)
                           ).lower(p_abs, opt_abs, batch).compile()
    hlo = compiled.as_text()
    ct = compute_totals(hlo)
    coll = collective_totals(hlo)
    model = 6 * cfg.param_count() * B * S
    hlo_global = ct["flops"] * 8
    assert model / 3 < hlo_global < model * 6, (model, hlo_global)
    assert coll["total_bytes"] > 0
