"""Net-subsystem tests: topology routing, analytic collective costs
against closed forms, compression/collective composition, planner
integration, and the local-SGD (DiLoCo-style) trainer."""

import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888
from repro.core.net import (NetParams, Topology, collective_cost,
                            hierarchical_allreduce, ring_allreduce,
                            sync_cost)
from repro.core.planner import dtfm
from repro.core.sched.carbon_aware import FleetDevice
from repro.optim.compress import CompressConfig, wire_bytes_count


def fleet(n, regions=("europe",), spec=LAPTOP_M2PRO):
    return [FleetDevice(spec=spec, region=regions[i % len(regions)],
                        device_id=i) for i in range(n)]


# ------------------------------------------------------------------ topology
def test_routing_hierarchy():
    topo = Topology.from_fleet(fleet(4, ("europe", "north_america")))
    # same region: 2 hops; cross region: 4 hops through the backbone
    assert len(topo.path("0", "2")) == 2
    assert len(topo.path("0", "1")) == 4
    assert topo.p2p_time_s(1e6, "0", "2") < topo.p2p_time_s(1e6, "0", "1")
    assert topo.path_bw_Bps("0", "2") == LAPTOP_M2PRO.net_bw_Bps


def test_wan_bottleneck_applies_cross_region_only():
    p = NetParams(wan_bw_Bps=1e6)          # WAN slower than access links
    topo = Topology.from_fleet(fleet(4, ("europe", "north_america")),
                               params=p)
    assert topo.path_bw_Bps("0", "1") == 1e6
    assert topo.path_bw_Bps("0", "2") == LAPTOP_M2PRO.net_bw_Bps


# ---------------------------------------------------------------- collectives
def test_ring_allreduce_matches_closed_form():
    p = NetParams(access_latency_s=0.005, access_jitter_s=0.002)
    topo = Topology.from_fleet(fleet(6), params=p)
    nbytes = 80e6
    c = ring_allreduce(topo, topo.devices, nbytes)
    n = 6
    bw = LAPTOP_M2PRO.net_bw_Bps
    delay = 2 * (0.005 + 0.002)            # two access hops per ring edge
    expect = 2 * (n - 1) / n * nbytes / bw + 2 * (n - 1) * delay
    assert c.time_s == pytest.approx(expect, rel=1e-12)
    # bandwidth-optimal volume: 2(N-1)/N * nbytes per device
    assert c.per_device_bytes["0"] == pytest.approx(
        2 * (n - 1) / n * nbytes)
    assert c.wan_bytes == 0.0


def test_hierarchical_beats_flat_ring_on_two_regions():
    p = NetParams(wan_bw_Bps=2e6, wan_latency_s=0.05)
    topo = Topology.from_fleet(fleet(16, ("europe", "north_america")),
                               params=p)
    nbytes = 100e6
    flat = ring_allreduce(topo, topo.devices, nbytes)
    hier = hierarchical_allreduce(topo, topo.devices, nbytes)
    assert hier.time_s < flat.time_s
    assert hier.wan_bytes < flat.wan_bytes


def test_hierarchical_degenerates_to_ring_on_one_region():
    topo = Topology.from_fleet(fleet(8))
    a = ring_allreduce(topo, topo.devices, 1e6)
    b = hierarchical_allreduce(topo, topo.devices, 1e6)
    assert b.time_s == pytest.approx(a.time_s)
    assert b.wire_bytes == pytest.approx(a.wire_bytes)


def test_collective_cost_trivial_group_and_unknown_algorithm():
    topo = Topology.from_fleet(fleet(2))
    assert collective_cost(topo, ["0"], 1e6, "ring").time_s == 0.0
    with pytest.raises(ValueError):
        collective_cost(topo, topo.devices, 1e6, "nope")


def test_sync_cost_composes_compression_and_interval():
    topo = Topology.from_fleet(fleet(4))
    n = 1_000_000
    full = sync_cost(topo, topo.devices, n, algorithm="ring",
                     compress=None, dtype_bytes=4)
    q8 = sync_cost(topo, topo.devices, n, algorithm="ring",
                   compress=CompressConfig(method="int8"), dtype_bytes=4)
    amort = sync_cost(topo, topo.devices, n, algorithm="ring",
                      compress=None, dtype_bytes=4, sync_interval=16)
    assert q8.wire_bytes < full.wire_bytes / 3     # ~4x over fp32
    assert amort.time_s == pytest.approx(full.time_s / 16)
    assert wire_bytes_count(n, None) == 4 * n


# -------------------------------------------------------------------- planner
def test_plan_rejects_oversubscribed_data_parallel():
    cfg = get_config("opt-125m")
    with pytest.raises(ValueError):
        dtfm.plan(cfg, [LAPTOP_M2PRO], batch=4, seq_len=64,
                  data_parallel=8)


def test_plan_topology_pricing_close_to_seed_model_single_region():
    """Single-region homogeneous fleets stay comparable to the seed's
    flat min-bandwidth scalar (the topology adds only latency terms)."""
    cfg = get_config("opt-125m")
    devs = [LAPTOP_M2PRO] * 3
    p = dtfm.plan(cfg, devs, batch=16, seq_len=512, microbatches=8)
    seed = dtfm.min_bw_comm_s(cfg, devs, batch=16, seq_len=512)
    assert p.comm_s_per_step >= seed                # latency can only add
    assert p.comm_s_per_step < seed * 1.5
    assert p.boundary_s_per_step > 0 and p.dp_sync_s_per_step == 0


def test_plan_local_update_amortizes_dp_sync():
    cfg = get_config("opt-125m")
    devs = [LAPTOP_M2PRO] * 2
    kw = dict(batch=16, seq_len=512, data_parallel=4,
              dp_regions=["europe", "europe", "north_america",
                          "north_america"], collective="hierarchical")
    every = dtfm.plan(cfg, devs, sync_interval=1, **kw)
    k16 = dtfm.plan(cfg, devs, sync_interval=16, **kw)
    assert k16.dp_sync_s_per_step == pytest.approx(
        every.dp_sync_s_per_step / 16)
    assert k16.step_time_s < every.step_time_s


def test_orchestrator_rebuilds_topology_and_charges_comm():
    from repro.configs.opt import opt_config
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")
    fl = make_fleet({"laptop-m2pro": 4}, seed=0)
    res = Orchestrator(cfg, fl, SimConfig(total_steps=20, seed=0)).run()
    assert res.topology_rebuilds >= 1
    assert res.comm_s_total > 0
    assert 0 < res.comm_energy_wh < res.energy_wh


# ---------------------------------------------------------------- compression
def test_compress_error_state_none_without_error_feedback():
    import jax.numpy as jnp
    from repro.optim.compress import compress_grads
    g = {"w": jnp.ones((64,), jnp.float32)}
    cfgc = CompressConfig(method="int8", error_feedback=False)
    sent, err = compress_grads(g, None, cfgc)
    assert err is None
    # toggling error feedback on afterwards must not crash on shapes
    cfgc_ef = CompressConfig(method="int8", error_feedback=True)
    sent2, err2 = compress_grads(g, err, cfgc_ef)
    assert err2["w"].shape == g["w"].shape


# ------------------------------------------------------------------ local SGD
def _tiny_cfg():
    cfg = get_config("opt-125m").reduced(num_layers=2, d_model=128,
                                         vocab_size=512)
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def test_local_sgd_k1_matches_plain_trainer():
    from repro.optim import adamw
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig, train
    cfg = _tiny_cfg()
    tc = TrainerConfig(steps=6, batch=4, seq_len=32, log_every=0, seed=3)
    opt = adamw.OptConfig(learning_rate=1e-3, warmup_steps=2,
                          decay_steps=6)
    plain = train(cfg, tc, opt_cfg=opt)
    loc = train_local_sgd(
        cfg, tc, LocalSGDConfig(replicas=1, inner_steps=1, outer_lr=1.0,
                                outer_momentum=0.0, nesterov=False),
        opt_cfg=opt)
    # identical trajectory up to fp32 rounding of g - (g - l)
    np.testing.assert_allclose(plain.losses, loc.losses, rtol=1e-5,
                               atol=1e-5)


def test_local_sgd_decreases_loss_quickstart_size():
    """Integration: DiLoCo-style training (2 replicas, K=4, int8-
    compressed outer sync) learns on the quickstart-size model."""
    from repro.optim import adamw
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig
    cfg = get_config("opt-125m").reduced(num_layers=4, d_model=256,
                                         vocab_size=2048)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    steps = 24
    topo = Topology.from_fleet(fleet(2, ("europe", "north_america")))
    res = train_local_sgd(
        cfg, TrainerConfig(steps=steps, batch=4, seq_len=64, log_every=0,
                           seed=0),
        LocalSGDConfig(replicas=2, inner_steps=4, outer_lr=0.7,
                       outer_momentum=0.9,
                       compress=CompressConfig(method="int8")),
        adamw.OptConfig(learning_rate=3e-3, warmup_steps=2,
                        decay_steps=steps),
        topology=topo, sync_algorithm="hierarchical")
    assert res.round_losses[-1] < res.round_losses[0] * 0.9
    assert res.comm_time_s_per_step == pytest.approx(
        res.comm_time_s_per_round / 4)
    assert res.sync_wire_bytes_per_round > 0
