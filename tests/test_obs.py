"""Fleet telemetry layer (``repro.obs``): span tracer + Chrome-trace
export, fixed-bucket histograms, the device-resident accumulator, the
disabled-tracer overhead contract, and the wiring through trainer /
local-SGD / serving engine / orchestrator / energy monitor."""

import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (Counter, DeviceAccumulator, Gauge, Histogram,
                       MetricsRegistry, NULL_SPAN, Tracer, get_tracer,
                       set_tracer)
from repro.obs.validate import (validate_chrome_trace,
                                validate_metrics_jsonl)

from conftest import tiny


@pytest.fixture
def tracer():
    """Enabled tracer installed as the process global; always restored."""
    tr = Tracer(enabled=True, process="test")
    old = set_tracer(tr)
    yield tr
    set_tracer(old)


# --------------------------------------------------------------------------- #
# Span tracer core
# --------------------------------------------------------------------------- #

def test_span_nesting_and_chrome_roundtrip(tracer, tmp_path):
    with tracer.span("outer", "test", step=3):
        time.sleep(0.002)
        with tracer.span("inner", "test") as sp:
            sp.set(found=True)
            time.sleep(0.001)
    tracer.instant("mark", "test", note="hi")
    tracer.counter("util", 0.5)

    by_name = {e["name"]: e for e in tracer.events}
    outer, inner = by_name["outer"], by_name["inner"]
    # the inner complete event nests inside the outer on the timeline
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1
    assert outer["args"] == {"step": 3}
    assert inner["args"] == {"found": True}
    assert inner["dur"] >= 1e3          # slept 1ms; ts/dur are in µs

    path = tmp_path / "trace.json"
    tracer.save_chrome_trace(str(path))
    counts = validate_chrome_trace(str(path))
    assert counts["X"] == 2 and counts["i"] == 1 and counts["C"] == 1

    data = json.loads(path.read_text())
    evs = data["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "test" for e in meta)
    x = [e for e in evs if e["ph"] == "X"]
    assert all(isinstance(e["ts"], float) and e["dur"] >= 0 for e in x)
    assert all(e["ph"] == "i" and e["s"] == "t" for e in evs
               if e["name"] == "mark")


def test_detached_spans_cross_frames(tracer):
    h1 = tracer.begin("queued", "serve", track="req:a", uid="a")
    h2 = tracer.begin("queued", "serve", track="req:b", uid="b")
    h2.end(state="admitted")
    h1.end(state="admitted")            # out of order: detached, no stack
    ends = {e["args"]["uid"]: e for e in tracer.events}
    assert ends["a"]["args"]["state"] == "admitted"
    assert ends["b"]["ts"] <= ends["a"]["ts"] + ends["a"]["dur"]
    # distinct tracks get distinct tids
    assert ends["a"]["tid"] != ends["b"]["tid"]


def test_annotate_lands_on_innermost_open_span(tracer):
    with tracer.span("phase", "test"):
        tracer.annotate(energy_j=1.5)
        with tracer.span("sub", "test"):
            tracer.annotate(carbon_g=0.2)
    by_name = {e["name"]: e for e in tracer.events}
    assert by_name["phase"]["args"]["energy_j"] == 1.5
    assert by_name["sub"]["args"]["carbon_g"] == 0.2
    tracer.annotate(lost=True)          # outside any span: no-op, no crash
    assert not any("lost" in e["args"] for e in tracer.events)


def test_explicit_timestamp_events_for_sim_clocks(tracer):
    tracer.complete("restore", ts_s=12.5, dur_s=3.0, cat="sched",
                    track="fleet", bytes_moved=100)
    tracer.instant("churn", "sched", track="fleet", ts_s=20.0)
    ev = {e["name"]: e for e in tracer.events}
    assert ev["restore"]["ts"] == 12.5e6 and ev["restore"]["dur"] == 3.0e6
    assert ev["churn"]["ts"] == 20.0e6


def test_disabled_tracer_is_shared_null_span():
    tr = Tracer(enabled=False)
    sp = tr.span("x", "y", big="attrs")
    assert sp is NULL_SPAN and tr.begin("z") is NULL_SPAN
    with sp as s:
        s.set(a=1).end(b=2)             # all no-ops
    tr.instant("i")
    tr.counter("c", 1.0)
    tr.complete("x", ts_s=0, dur_s=1)
    tr.annotate(q=1)
    assert tr.events == []


def test_disabled_tracer_overhead_under_2pct():
    """The acceptance contract: one span per iteration of a tight loop on
    a DISABLED tracer stays under 2% of a ~50µs step body — i.e. the
    net per-call cost (span construction + with-enter/exit, min over
    repeats to shed scheduler noise) must be < 1µs.  Measured directly
    rather than as a wall-clock ratio: on shared CI hosts the body's
    own run-to-run jitter exceeds the span cost by an order of
    magnitude, which would make a ratio assertion test the host, not
    the tracer."""
    import timeit
    tr = Tracer(enabled=False)

    def with_span():
        with tr.span("step", "train", metric="train/step_s"):
            pass

    def bare():
        pass

    n = 50_000
    per_call = min(timeit.repeat(with_span, number=n, repeat=7)) / n
    floor = min(timeit.repeat(bare, number=n, repeat=7)) / n
    net_s = per_call - floor
    assert net_s < 1e-6, \
        f"disabled span costs {net_s*1e9:.0f} ns/call " \
        f"({net_s/50e-6:.2%} of a 50µs step body; budget 2%)"
    assert tr.events == []


def test_span_metric_feeds_registry_histogram():
    reg = MetricsRegistry()
    tr = Tracer(enabled=True, registry=reg)
    for _ in range(4):
        with tr.span("step", "train", metric="train/step_s"):
            time.sleep(0.001)
    h = reg.histogram("train/step_s")
    assert h.count == 4 and h.min >= 1e-3


# --------------------------------------------------------------------------- #
# Metrics: histograms / counters / gauges / registry
# --------------------------------------------------------------------------- #

def test_histogram_percentiles_vs_numpy():
    rng = np.random.RandomState(0)
    samples = np.exp(rng.normal(-2.0, 1.5, size=5000))   # spans decades
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    # default layout: 120 log buckets over [1e-7, 1e4) — bucket edge
    # ratio (1e4/1e-7)^(1/120) ≈ 1.235, so interpolation is good to
    # ~25% relative; the tests pin half that margin above it
    for q in (50, 95, 99):
        ref = float(np.percentile(samples, q))
        got = h.percentile(q)
        assert abs(got - ref) / ref < 0.35, (q, got, ref)
    assert h.count == len(samples)
    assert math.isclose(h.sum, float(samples.sum()), rel_tol=1e-9)
    assert h.min == samples.min() and h.max == samples.max()


def test_histogram_edges_and_empty():
    h = Histogram(lo=1e-3, hi=1e3, nbuckets=10)
    assert math.isnan(h.percentile(50))
    h.observe(1e-5)                     # underflow
    h.observe(1e5)                      # overflow
    assert h.percentile(0) >= 1e-5 and h.percentile(100) <= 1e5
    snap = h.snapshot()
    assert snap["count"] == 2 and "p99" in snap
    with pytest.raises(ValueError):
        Histogram(lo=0.0)


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    reg.counter("a").inc(2)
    reg.counter("a").inc(3)
    assert reg.counter("a").value == 5
    g = reg.gauge("peak")
    g.set_max(0.3)
    g.set_max(0.1)                      # high-water keeps the peak
    assert g.value == 0.3
    with pytest.raises(TypeError):
        reg.histogram("a")
    assert "a" in reg and reg.names() == ["a", "peak"]


def test_metrics_dump_jsonl_schema(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve/tokens").inc(7)
    reg.gauge("serve/kv_utilization_peak").set_max(0.4)
    reg.histogram("serve/ttft_s").observe(0.01)
    path = tmp_path / "metrics.jsonl"
    reg.dump_jsonl(str(path), meta={"arch": "opt-125m"})
    counts = validate_metrics_jsonl(str(path))
    assert counts == {"meta": 1, "metric": 3}


def test_tracer_jsonl_event_log_validates(tmp_path, tracer):
    with tracer.span("step", "train"):
        pass
    path = tmp_path / "events.jsonl"
    tracer.save_jsonl(str(path))
    assert validate_metrics_jsonl(str(path)) == {"event": 1}


def test_validate_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X", "pid": 1}]}))
    with pytest.raises(ValueError):
        validate_chrome_trace(str(bad))
    empty = tmp_path / "no_spans.json"
    empty.write_text(json.dumps({"traceEvents": [
        {"ph": "M", "pid": 1, "tid": 0, "name": "process_name"}]}))
    with pytest.raises(ValueError, match="no complete"):
        validate_chrome_trace(str(empty))


def test_validate_bench_json_schema_and_claims(tmp_path):
    """Bench artifacts: dispatched by shape (meta, no traceEvents);
    provenance keys are required and embedded claim verdicts must hold."""
    from repro.obs.validate import validate, validate_bench_json
    meta = {"commit": "abc123", "timestamp_utc": "2026-01-01T00:00:00Z",
            "jax_version": "0.0", "backend": "cpu"}
    good = tmp_path / "BENCH_x.json"
    good.write_text(json.dumps({
        "meta": meta,
        "claims": [{"text": "t", "value": 1.5, "lo": 1.3,
                    "hi": float("inf"), "ok": True}]}))
    assert validate(str(good)) == {"meta": 1, "claim": 1}

    no_meta = tmp_path / "no_meta.json"
    no_meta.write_text(json.dumps({"meta": {"commit": "abc"}}))
    with pytest.raises(ValueError, match="meta missing"):
        validate_bench_json(str(no_meta))

    failed = tmp_path / "failed.json"
    failed.write_text(json.dumps({
        "meta": meta,
        "claims": [{"text": "t", "value": 1.1, "lo": 1.3, "hi": 2.0,
                    "ok": False}]}))
    with pytest.raises(ValueError, match="claim 0 FAILED"):
        validate_bench_json(str(failed))


def test_device_accumulator_matches_eager_bit_for_bit():
    """Batched drain must route EXACTLY the values eager float() would:
    one device_get at the window boundary, zero numerical difference."""
    reg_acc, reg_eager = MetricsRegistry(), MetricsRegistry()
    acc = DeviceAccumulator(reg_acc)
    xs = [jnp.float32(1.0) / (i + 3) * jnp.sin(jnp.float32(i))
          for i in range(17)]
    for i, x in enumerate(xs):
        acc.observe("loss", x)
        if i % 3 == 0:
            acc.inc("steps", jnp.int32(1))
    drained = acc.drain()
    assert len(acc) == 0 and acc.drain() == []
    k = 0
    for i, x in enumerate(xs):
        reg_eager.histogram("loss").observe(float(x))
        k += 1
        if i % 3 == 0:
            reg_eager.counter("steps").inc(float(jnp.int32(1)))
            k += 1
    assert len(drained) == k
    a, b = reg_acc.snapshot(), reg_eager.snapshot()
    assert a["loss"]["sum"] == b["loss"]["sum"]          # bit-for-bit
    assert a["loss"]["min"] == b["loss"]["min"]
    assert a["loss"]["max"] == b["loss"]["max"]
    assert a["steps"]["value"] == b["steps"]["value"]


# --------------------------------------------------------------------------- #
# Energy monitor calibration (+ span attribution)
# --------------------------------------------------------------------------- #

def _monitor():
    from repro.core.energy.devices import LAPTOP_M2PRO
    from repro.core.energy.monitor import ComponentModel, EnergyMonitor
    return EnergyMonitor(ComponentModel.for_device(LAPTOP_M2PRO))


def test_energy_calibrate_full_history():
    mon = _monitor()
    for i in range(4):
        mon.record_step(flops=1e9 * (i + 1), duration_s=0.1)
    scale = mon.calibrate(measured_j=2.0)
    assert scale == pytest.approx(2.0 / sum(mon.raw_j))
    assert mon.total_j == pytest.approx(2.0)


def test_energy_calibrate_windowed_rescales_consistently():
    """Regression: windowed calibrate must (a) derive the scale from the
    window's UNSCALED raws and (b) rescale every recorded estimate, so
    totals never mix scales and repeated calibrations don't compound."""
    mon = _monitor()
    for i in range(6):
        mon.record_step(flops=2e9, duration_s=0.05 * (i + 1))
    s1 = mon.calibrate(measured_j=3.0, window=2)
    assert s1 == pytest.approx(3.0 / sum(mon.raw_j[-2:]))
    # every entry sits on the ONE new scale — estimate_i == raw_i * s1
    for r, e in zip(mon.raw_j, mon.estimates_j):
        assert e == pytest.approx(r * s1)
    assert sum(mon.estimates_j[-2:]) == pytest.approx(3.0)
    # idempotent: same measurement, same window -> same scale (the old
    # buggy form divided by already-scaled estimates and compounded)
    assert mon.calibrate(measured_j=3.0, window=2) == pytest.approx(s1)
    # and further steps record on the calibrated scale
    e_next = mon.record_step(flops=2e9, duration_s=0.05)
    assert e_next == pytest.approx(mon.raw_j[-1] * s1)


def test_energy_calibrate_empty_is_noop():
    mon = _monitor()
    assert mon.calibrate(measured_j=5.0) == 1.0
    mon.reset()
    assert mon.scale == 1.0 and mon.raw_j == [] and mon.estimates_j == []


def test_energy_and_carbon_annotate_enclosing_span(tracer):
    from repro.core.carbon.accounting import CarbonLedger
    mon = _monitor()
    led = CarbonLedger()
    with tracer.span("engine_step", "serve"):
        mon.record_step(flops=1e9, duration_s=0.01)
        led.add_operational_kwh("serve", 1e-6)
    (ev,) = tracer.events
    assert ev["args"]["energy_j"] == pytest.approx(mon.estimates_j[0])
    assert ev["args"]["carbon_g"] == pytest.approx(
        led.operational_kg * 1000.0)


# --------------------------------------------------------------------------- #
# Trainer + local SGD wiring
# --------------------------------------------------------------------------- #

def _opt_tiny():
    from repro.configs import get_config
    return tiny(get_config("opt-125m"))


def test_trainer_emits_phase_spans_and_metrics(tracer, tmp_path):
    from repro.train.trainer import TrainerConfig, train
    reg = MetricsRegistry()
    tracer.registry = reg
    tc = TrainerConfig(steps=4, batch=2, seq_len=16, log_every=2)
    train(_opt_tiny(), tc, metrics=reg)

    names = {e["name"] for e in tracer.events}
    assert {"step", "data", "fwd_bwd_opt", "metrics_drain"} <= names
    steps = [e for e in tracer.events if e["name"] == "step"]
    assert len(steps) == 4
    assert [e["args"]["step"] for e in steps] == [0, 1, 2, 3]
    # phase spans nest inside their step span on the timeline
    s0 = steps[0]
    inner = [e for e in tracer.events
             if e["name"] in ("data", "fwd_bwd_opt")
             and s0["ts"] <= e["ts"] <= s0["ts"] + s0["dur"]]
    assert inner, "no phase spans inside step 0"

    snap = reg.snapshot()
    assert snap["train/step_s"]["count"] == 4       # span metric= hook
    assert snap["train/loss"]["count"] == 4         # device-acc drained
    assert snap["train/grad_norm"]["count"] == 4
    assert snap["train/steps"]["value"] == 4
    assert snap["train/tokens"]["value"] == 4 * 2 * 16

    path = tmp_path / "train_trace.json"
    tracer.save_chrome_trace(str(path))
    assert validate_chrome_trace(str(path))["X"] >= 4


def test_local_sgd_round_spans_and_pseudograd_bytes(tracer, tmp_path):
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig
    reg = MetricsRegistry()
    tracer.registry = reg
    tc = TrainerConfig(steps=4, batch=2, seq_len=16, log_every=0)
    ls = LocalSGDConfig(replicas=2, inner_steps=2)
    res = train_local_sgd(_opt_tiny(), tc, ls, metrics=reg)

    names = {e["name"] for e in tracer.events}
    assert {"round", "inner_step", "pseudograd", "outer_sync"} <= names
    syncs = [e for e in tracer.events if e["name"] == "outer_sync"]
    assert len(syncs) == res.rounds == 2
    assert syncs[0]["args"]["wire_bytes_per_replica"] == \
        res.sync_wire_bytes_per_round

    snap = reg.snapshot()
    assert snap["local_sgd/rounds"]["value"] == res.rounds
    # per-round wire accounting: R replicas ship one pseudo-gradient each
    assert snap["local_sgd/pseudograd_bytes"]["value"] == \
        res.sync_wire_bytes_per_round * ls.replicas * res.rounds
    assert snap["local_sgd/round_s"]["count"] == res.rounds
    assert snap["local_sgd/inner_step_s"]["count"] == \
        res.rounds * ls.replicas * ls.inner_steps

    path = tmp_path / "local_sgd_trace.json"
    tracer.save_chrome_trace(str(path))
    assert validate_chrome_trace(str(path))["X"] >= 4


# --------------------------------------------------------------------------- #
# Serving engine wiring
# --------------------------------------------------------------------------- #

def _serve_setup(tracer, *, num_blocks=40, n=4):
    import dataclasses

    from repro.configs import get_config
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = dataclasses.replace(tiny(get_config("qwen2-7b")), num_kv_heads=2)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    reqs = [Request(uid=f"r{i}",
                    prompt=list(np.random.RandomState(i).randint(
                        0, cfg.vocab_size, 3 + 3 * i)),
                    max_new=5 + (3 * i) % 7)
            for i in range(n)]
    eng = ServeEngine(params, cfg, EngineConfig(
        max_slots=3, block_size=4, num_blocks=num_blocks,
        max_blocks_per_seq=8))
    return eng, reqs


def test_engine_request_lifecycle_spans_and_ttft(tracer, tmp_path):
    eng, reqs = _serve_setup(tracer)
    out = eng.run(reqs)
    assert set(out) == {r.uid for r in reqs}

    # every request's track tells queued -> prefill -> decode(finished)
    for r in reqs:
        track_tid = tracer._tracks[f"req:{r.uid}"]
        phases = [e for e in tracer.events
                  if e["tid"] == track_tid and e["ph"] == "X"]
        seq = [(e["name"], e["args"].get("state")) for e in phases]
        assert ("queued", "admitted") in seq
        assert ("prefill", "prefilled") in seq
        assert ("decode", "finished") in seq
        fin = next(e for e in phases if e["args"].get("state") == "finished")
        assert fin["args"]["tokens"] == len(out[r.uid].tokens)

    s = eng.stats()
    assert 0 < s["ttft_p50_s"] <= s["ttft_p99_s"]
    assert s["req_tokens_per_s_p50"] > 0
    snap = eng.metrics.snapshot()
    assert snap["serve/ttft_s"]["count"] == len(reqs)
    assert snap["serve/tokens_per_s"]["count"] == len(reqs)
    assert snap["serve/requests_finished"]["value"] == len(reqs)
    assert snap["serve/tokens"]["value"] == sum(
        len(c.tokens) for c in out.values())
    # the engine_step metric= hook feeds the TRACER's attached registry
    # (unset here), not the engine's own — the windows stay separable
    assert "serve/step_s" not in snap

    path = tmp_path / "serve_trace.json"
    tracer.save_chrome_trace(str(path))
    counts = validate_chrome_trace(str(path))
    assert counts["X"] >= 3 * len(reqs) and counts.get("C", 0) > 0


def test_engine_kv_peak_survives_drain(tracer):
    """Satellite: per-step high-water KV stats from the registry stay
    nonzero AFTER every request finished and all blocks were freed —
    the instantaneous kv.stats() read zero by then."""
    eng, reqs = _serve_setup(tracer)
    eng.run(reqs)
    assert eng.kv.stats()["utilization"] == 0.0     # all evicted
    s = eng.stats()
    assert s["utilization_peak"] > 0.0
    assert eng.metrics.gauge("serve/kv_utilization_peak").value > 0.0
    assert eng.metrics.histogram("serve/kv_utilization",
                                 lo=1e-4, hi=2.0).count == eng.steps


def test_engine_preemption_keeps_ttft_clock(tracer):
    """TTFT is submit -> first EVER token: preempted-then-requeued
    requests must not reset the clock or double-observe."""
    eng, reqs = _serve_setup(tracer, num_blocks=9)
    out = eng.run(reqs)
    assert sum(c.preemptions for c in out.values()) > 0
    assert eng.metrics.counter("serve/preemptions").value > 0
    assert eng.metrics.histogram("serve/ttft_s").count == len(reqs)
    # a preempted phase span closed with state=preempted, then requeued
    states = [e["args"].get("state") for e in tracer.events
              if e["ph"] == "X"]
    assert "preempted" in states
    assert any(e["name"] == "queued" and e["args"].get("requeued")
               for e in tracer.events)
    assert any(e["name"] == "preempt" and e["ph"] == "i"
               for e in tracer.events)


def test_engine_without_tracer_still_serves():
    """Default (disabled) tracer: no events, but registry metrics and
    stats still work — telemetry is opt-in, never load-bearing."""
    assert not get_tracer().enabled
    eng, reqs = _serve_setup(get_tracer())
    out = eng.run(reqs)
    assert len(out) == len(reqs)
    assert get_tracer().events == []
    assert eng.stats()["ttft_p50_s"] > 0


# --------------------------------------------------------------------------- #
# Orchestrator fleet events
# --------------------------------------------------------------------------- #

def test_orchestrator_fleet_timeline_on_sim_clock(tracer, tmp_path):
    from repro.configs.opt import opt_config
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")
    fleet = make_fleet({"laptop-m2pro": 4, "smartphone-sd888": 6},
                       regions=("europe", "north_america"), seed=2)
    r = Orchestrator(cfg, fleet, SimConfig(
        total_steps=60, seed=5, checkpoint_interval=20)).run()

    names = [e["name"] for e in tracer.events]
    assert names.count("step") == r.steps_done
    assert "replan" in names and "ckpt_write" in names
    if r.membership_changes:
        assert "churn" in names
    if r.restores:
        assert "restore" in names
    # events ride the SIMULATED clock: monotone non-decreasing sim time,
    # total span ~ the sim's wall result (µs = s * 1e6)
    steps = [e for e in tracer.events if e["name"] == "step"]
    ts = [e["ts"] for e in steps]
    assert ts == sorted(ts)
    assert steps[-1]["ts"] + steps[-1]["dur"] <= r.wall_time_s * 1e6 + 1
    assert all("energy_wh" in e["args"] for e in steps)
    samples = [e for e in tracer.events if e["name"] == "fleet.active"]
    assert len(samples) == r.steps_done
    assert all(e["ph"] == "C" for e in samples)

    path = tmp_path / "fleet_trace.json"
    tracer.save_chrome_trace(str(path))
    assert validate_chrome_trace(str(path))["X"] >= r.steps_done
