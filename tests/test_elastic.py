"""Elastic state contract: placement-aware checkpoint sharding, live
resharding across stage boundaries, priced recovery, trainer/local-SGD
resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointSpec, ckpt, recovery_cost,
                              state_layer_bytes, write_cost)
from repro.configs.opt import opt_config
from repro.core.energy.devices import LAPTOP_M2PRO, SMARTPHONE_SD888
from repro.core.net import NetParams, Topology
from repro.core.placement import search_placement
from repro.core.sched.carbon_aware import FleetDevice
from repro.models import params as P
from repro.optim import adamw

L = 6


def _cfg():
    return opt_config("opt-125m").reduced(num_layers=L, d_model=64,
                                          vocab_size=64)


def _state(cfg, seed=0):
    params = P.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init_opt_state(params, adamw.OptConfig())
    return {"params": params, "opt": opt}


def _assert_trees_bitexact(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        xa, ya = np.asarray(x), np.asarray(y)
        if xa.dtype.kind == "V":
            xa, ya = xa.view(np.uint16), ya.view(np.uint16)
        np.testing.assert_array_equal(xa, ya)


def _two_region_fleet(n=8):
    fleet = []
    for i in range(n):
        region = ("europe", "north_america")[i % 2]
        spec = (LAPTOP_M2PRO, SMARTPHONE_SD888)[(i // 2) % 2]
        fleet.append(FleetDevice(spec=spec, region=region, device_id=i))
    return fleet


def _placement(cfg, fleet, dp=2):
    topo = Topology.from_fleet(fleet, params=NetParams(wan_bw_Bps=5e6))
    return search_placement(
        cfg, [d.spec for d in fleet], topology=topo,
        nodes=[str(d.device_id) for d in fleet], data_parallel=dp,
        batch=8, seq_len=64, microbatches=2, collective="hierarchical")


# ------------------------------------------------------------------- spec
def test_spec_from_placement_boundaries_and_holders():
    cfg = _cfg()
    fleet = _two_region_fleet()
    pl = _placement(cfg, fleet)
    spec = CheckpointSpec.from_placement(pl, replication=1)
    assert list(spec.boundaries) == pl.boundaries
    assert spec.num_shards == pl.num_stages
    # every replica's stage-s node holds shard s...
    for s in range(spec.num_shards):
        for pipe in pl.pipelines:
            assert pipe[s].node in spec.holders[s]
        # ...and with replication=1 the next stage's nodes hold it too
        nxt = (s + 1) % spec.num_shards
        for pipe in pl.pipelines:
            assert pipe[nxt].node in spec.holders[s]


def test_spec_validates():
    with pytest.raises(ValueError):
        CheckpointSpec(L, (0, 3, 3, L))          # duplicate boundary
    with pytest.raises(ValueError):
        CheckpointSpec(L, (1, L))                # must start at 0
    with pytest.raises(ValueError):
        CheckpointSpec(L, (0, 3, L), replication=2)   # r > S-1


# --------------------------------------------------------------- reshard
def test_restore_onto_different_boundaries(tmp_path):
    """A 3-stage checkpoint restores identically through any new
    placement's boundaries — the manifest, not the caller, says how the
    layer arrays were sliced."""
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save_for_placement(str(tmp_path), 5, tree,
                            CheckpointSpec(L, (0, 2, 4, L)))
    for bounds in ((0, 3, L), (0, L), (0, 1, 2, 3, 4, 5, L)):
        back = ckpt.restore_for_placement(str(tmp_path), list(bounds), tree)
        _assert_trees_bitexact(tree, back)


def test_reshard_roundtrip_bitexact(tmp_path):
    cfg = _cfg()
    tree = _state(cfg)
    d1, d2, d3 = (tmp_path / x for x in ("a", "b", "c"))
    ckpt.save_for_placement(str(d1), 7, tree,
                            CheckpointSpec(L, (0, 2, 4, L), replication=1))
    ckpt.reshard(str(d1), CheckpointSpec(L, (0, 3, L)), tree,
                 out_directory=str(d2))
    ckpt.reshard(str(d2), CheckpointSpec(L, (0, 2, 4, L)), tree,
                 out_directory=str(d3))
    _assert_trees_bitexact(tree, ckpt.restore(str(d3), tree))
    # the resharded copy keeps the original step number
    assert ckpt.latest_step(str(d2)) == 7


def test_stage_partial_restore_matches_pipeline_slices(tmp_path):
    """restore_for_placement(stage=s) returns exactly the layer span the
    pipeline executor would stack for that stage — one boundary math."""
    from repro.distributed.pipeline import stage_slices
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save_for_placement(str(tmp_path), 1, tree,
                            CheckpointSpec(L, (0, 2, 4, L)))
    new_bounds = [0, 3, L]
    full = ckpt.restore(str(tmp_path), tree)
    for s, (a, b) in enumerate(stage_slices(new_bounds)):
        part = ckpt.restore_for_placement(str(tmp_path), new_bounds, tree,
                                          stage=s)
        wq_full = np.asarray(
            full["params"]["decoder"]["g0"]["s0_attn"]["wq"])
        wq_part = np.asarray(
            part["params"]["decoder"]["g0"]["s0_attn"]["wq"])
        assert wq_part.shape[0] == b - a
        np.testing.assert_array_equal(wq_part, wq_full[a:b])
        # placement-independent leaves come back whole
        assert np.asarray(part["params"]["embed"]["tok"]).shape == \
            np.asarray(full["params"]["embed"]["tok"]).shape


def test_stage_partial_restore_from_legacy_layout(tmp_path):
    """stage= also crops checkpoints written by the legacy leaf-modulo
    save (whole-leaf files; the crop happens after the read)."""
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save(str(tmp_path), 1, tree)
    part = ckpt.restore_for_placement(str(tmp_path), [0, 2, L], tree,
                                      stage=0)
    wq = np.asarray(part["params"]["decoder"]["g0"]["s0_attn"]["wq"])
    full = np.asarray(tree["params"]["decoder"]["g0"]["s0_attn"]["wq"])
    np.testing.assert_array_equal(wq, full[:2])


def test_save_for_placement_replication_override(tmp_path):
    """An explicit nonzero replication= beats the spec's own value."""
    import json
    cfg = _cfg()
    tree = _state(cfg)
    ckpt.save_for_placement(str(tmp_path), 1, tree,
                            CheckpointSpec(L, (0, 2, 4, L)), replication=1)
    m = json.loads((tmp_path / "step_00000001"
                    / "manifest_0.json").read_text())
    assert m["replication"] == 1


def test_replicated_shards_survive_writer_loss(tmp_path):
    """§5 neighbour replication: with replication=1 the union minus any
    single writer still restores completely."""
    cfg = _cfg()
    tree = _state(cfg)
    spec = CheckpointSpec(L, (0, 2, 4, L), replication=1)
    # writer 1 crashed before writing anything
    for shard in (0, 2):
        ckpt.save_sharded(str(tmp_path), 3, tree, spec, shard)
    _assert_trees_bitexact(tree, ckpt.restore(str(tmp_path), tree))
    # without replication the same crash is detected, loudly
    spec0 = CheckpointSpec(L, (0, 2, 4, L))
    for shard in (0, 2):
        ckpt.save_sharded(str(tmp_path / "r0"), 3, tree, spec0, shard)
    with pytest.raises(ckpt.IncompleteCheckpointError, match="shard 1"):
        ckpt.restore(str(tmp_path / "r0"), tree)


# ---------------------------------------------------------------- pricing
def test_recovery_cheaper_than_naive_and_free_for_survivors():
    cfg = opt_config("opt-125m")
    fleet = _two_region_fleet()
    pl = _placement(cfg, fleet)
    layer_b, global_b = state_layer_bytes(cfg)
    spec = CheckpointSpec.from_placement(pl, replication=1)
    topo = pl.topology
    # restoring onto the SAME placement moves zero bytes (everyone
    # already holds their shard)
    same = recovery_cost(topo, pl, old_spec=spec, layer_bytes=layer_b,
                         global_bytes=global_b)
    assert same.bytes_moved == 0.0 and same.time_s == 0.0
    # churn: a device leaves, the new placement pays only missing bytes
    survivors = fleet[1:]
    topo2 = Topology.from_fleet(survivors,
                                params=NetParams(wan_bw_Bps=5e6))
    pl2 = _placement(cfg, survivors)
    kw = dict(old_spec=spec, layer_bytes=layer_b, global_bytes=global_b)
    aware = recovery_cost(topo2, pl2, **kw)
    naive = recovery_cost(topo2, pl2, naive=True, **kw)
    assert 0.0 < aware.bytes_moved < naive.bytes_moved
    assert aware.wan_bytes < naive.wan_bytes
    assert aware.time_s < naive.time_s
    assert naive.wan_bytes == naive.bytes_moved      # store is WAN


def test_write_cost_scales_with_replication():
    cfg = opt_config("opt-125m")
    pl = _placement(cfg, _two_region_fleet())
    layer_b, global_b = state_layer_bytes(cfg)
    topo = pl.topology
    costs = [write_cost(topo, pl,
                        CheckpointSpec.from_placement(pl, r),
                        layer_b, global_b)
             for r in range(pl.num_stages)]
    for a, b in zip(costs[:-1], costs[1:]):
        assert b.bytes_moved > a.bytes_moved     # each copy costs bytes
    assert costs[0].bytes_moved > 0              # durable upload always


# ----------------------------------------------------------- orchestrator
def test_orchestrator_accounts_recovery_bytes():
    from repro.core.sched.orchestrator import (Orchestrator, SimConfig,
                                               make_fleet)
    cfg = opt_config("opt-125m")

    def run(naive):
        fl = make_fleet({"laptop-m2pro": 3, "smartphone-sd888": 4},
                        regions=("europe", "north_america"), seed=2)
        return Orchestrator(cfg, fl, SimConfig(
            total_steps=60, seed=5, checkpoint_interval=15,
            naive_restore=naive)).run()

    aware, naive = run(False), run(True)
    assert aware.ckpt_writes >= 1
    assert aware.ckpt_bytes_written > 0 and aware.ckpt_write_s_total > 0
    assert set(aware.ckpt_bytes_by_region) >= {"store"}
    # identical churn trajectory (pricing consumes no randomness)...
    assert aware.membership_changes == naive.membership_changes
    assert aware.restores == naive.restores
    # ...but the aware restore moves fewer bytes and less wall time
    if aware.restores:
        assert aware.restore_bytes_moved < naive.restore_bytes_moved
        assert aware.restore_s_total <= naive.restore_s_total
        assert aware.recovery_energy_wh > 0
        assert sum(aware.restore_bytes_by_region.values()) == \
            pytest.approx(aware.restore_bytes_moved)


def test_priced_fault_model_prefers_elastic_restore():
    from repro.core.sched.faults import pareto_frontier, priced_fault_model
    cfg = opt_config("opt-125m")
    pl = _placement(cfg, _two_region_fleet())
    fm = priced_fault_model(cfg, pl, lambda_per_device_hour=0.5,
                            step_time_s=30.0, stage_recompute_s=600.0,
                            replication=1)
    assert 0 < fm.elastic_restore_s < fm.ckpt_restore_s
    # elastic checkpointing dominates plain checkpointing at equal
    # intervals (same write cost, strictly cheaper restores)
    from repro.core.sched.faults import checkpoint_outcome
    plain = checkpoint_outcome(fm, 50)
    elastic = checkpoint_outcome(fm, 50, elastic=True)
    assert elastic.slowdown < plain.slowdown
    names = " ".join(s.name for s in pareto_frontier(fm))
    assert "checkpoint@" not in names.replace("elastic-ckpt@", "")


# --------------------------------------------------------------- training
def test_trainer_checkpoints_via_placement_and_resumes(tmp_path):
    from repro.train.trainer import TrainerConfig, train
    cfg = _cfg()
    pl_cfg = CheckpointSpec(L, (0, 2, 4, L), replication=1)
    tc = TrainerConfig(steps=4, batch=2, seq_len=16, log_every=0,
                       checkpoint_every=2, checkpoint_dir=str(tmp_path),
                       checkpoint_placement=pl_cfg,
                       checkpoint_replication=1, seed=3)
    train(cfg, tc)
    assert ckpt.latest_complete_step(str(tmp_path)) == 4
    # the checkpoint really is layer-sliced (3 shard manifests)
    step_dir = tmp_path / "step_00000004"
    assert len(list(step_dir.glob("manifest_*.json"))) == 3
    saved = ckpt.restore(str(tmp_path),
                         _state(cfg), step=4)
    # resume continues the step numbering and starts from the saved state
    tc2 = TrainerConfig(steps=2, batch=2, seq_len=16, log_every=0,
                        checkpoint_every=2, checkpoint_dir=str(tmp_path),
                        resume=True, seed=3)
    res = train(cfg, tc2)
    assert res.resumed_from_step == 4
    assert ckpt.latest_complete_step(str(tmp_path)) == 6
    # the resumed run's optimizer picked up where the saved state stopped
    resumed = ckpt.restore(str(tmp_path), _state(cfg), step=6)
    assert int(resumed["opt"]["step"]) == int(saved["opt"]["step"]) + 2


def test_local_sgd_persists_outer_state_and_resumes(tmp_path):
    from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
    from repro.train.trainer import TrainerConfig
    cfg = _cfg()
    tc = TrainerConfig(steps=4, batch=2, seq_len=16, log_every=0, seed=1)
    ls = LocalSGDConfig(replicas=2, inner_steps=2, checkpoint_dir=str(
        tmp_path), checkpoint_every_rounds=1, resume=False)
    train_local_sgd(cfg, tc, ls)
    assert ckpt.latest_complete_step(str(tmp_path)) == 2
    params = P.init_params(cfg, jax.random.PRNGKey(1))
    momentum = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
    state = ckpt.restore(str(tmp_path),
                         {"params": params, "outer_m": momentum})
    # outer momentum was actually persisted (non-zero after 2 rounds)
    m_norm = sum(float(jnp.sum(jnp.abs(x)))
                 for x in jax.tree.leaves(state["outer_m"]))
    assert m_norm > 0
    ls2 = LocalSGDConfig(replicas=2, inner_steps=2,
                         checkpoint_dir=str(tmp_path),
                         checkpoint_every_rounds=1, resume=True)
    res = train_local_sgd(cfg, tc, ls2)
    assert res.resumed_from_round == 2
    assert ckpt.latest_complete_step(str(tmp_path)) == 4
    # with a placement, the outer state shards over the spec's stage
    # slots (one manifest per stage, replication per config)
    pl = _placement(cfg, _two_region_fleet(), dp=2)
    ls3 = LocalSGDConfig(replicas=2, inner_steps=2,
                         checkpoint_dir=str(tmp_path / "pl"),
                         checkpoint_every_rounds=2,
                         checkpoint_replication=1)
    train_local_sgd(cfg, tc, ls3, placement=pl)
    step_dir = tmp_path / "pl" / "step_00000002"
    assert len(list(step_dir.glob("manifest_*.json"))) == pl.num_stages
