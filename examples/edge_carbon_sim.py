"""Edge-fleet orchestration demo: the paper's §4-§5 vision, end to end.

    PYTHONPATH=src python examples/edge_carbon_sim.py [--steps 300]

Simulates training OPT-125m over a dynamic, heterogeneous edge fleet
(laptops + smartphones across clean/dirty grids) with the framework's
orchestration layer: carbon-aware admission, thermal throttling, churn,
checkpoint-based fault tolerance.  Compares carbon-blind vs carbon-aware
policies and prints the offloading analysis of §4.2 Figs. 4-5 for the
selected fleet.
"""

from __future__ import annotations

import argparse

from repro.configs.opt import opt_config
from repro.core.carbon.offload import baseline_footprint, offload_analysis
from repro.core.energy.devices import CLOUD_H100, LAPTOP_M2PRO, SMARTPHONE_SD888
from repro.core.sched.carbon_aware import carbon_rate
from repro.core.sched.orchestrator import Orchestrator, SimConfig, make_fleet


def run_policy(cfg, fleet, steps: int, threshold: float, label: str):
    sim = SimConfig(total_steps=steps, seed=7,
                    carbon_threshold_g_per_gflop=threshold)
    res = Orchestrator(cfg, fleet, sim).run()
    print(f"\n--- {label} ---")
    print(f"  wall time          : {res.wall_time_s/3600:.2f} h")
    print(f"  throughput         : {res.throughput_steps_per_hour:.1f} steps/h")
    print(f"  energy             : {res.energy_wh:.1f} Wh")
    print(f"  operational carbon : {res.carbon_kg*1000:.2f} gCO2e")
    print(f"  rework (fault)     : {res.rework_steps} steps")
    print(f"  membership changes : {res.membership_changes}")
    print(f"  mean active devices: {res.mean_active_devices:.1f}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    cfg = opt_config("opt-125m")
    fleet = make_fleet({"laptop-m2pro": 8, "smartphone-sd888": 16},
                       regions=("nordics", "europe", "india"), seed=3)

    rates = sorted(carbon_rate(d, 12.0, {})[0] for d in fleet)
    median = rates[len(rates) // 2]

    blind = run_policy(cfg, fleet, args.steps, float("inf"),
                       "carbon-blind (admit everyone charging)")
    aware = run_policy(cfg, fleet, args.steps, median,
                       "carbon-aware (admit below median gCO2e/GFLOP)")
    if aware.carbon_kg > 0:
        print(f"\ncarbon-aware saves "
              f"{(1 - aware.carbon_kg/blind.carbon_kg)*100:.0f}% CO2e at "
              f"{aware.throughput_steps_per_hour/blind.throughput_steps_per_hour:.2f}x"
              " the throughput")

    # the paper's offloading headline, §4.2 Fig. 5, for this fleet's classes
    print("\n--- offloading analysis (one H100 replaced, 3 years) ---")
    for dev in (SMARTPHONE_SD888, LAPTOP_M2PRO):
        fp = baseline_footprint(dev)
        out = offload_analysis(dev, CLOUD_H100, use_paper_counts=True)
        print(f"  {dev.name:18s} ownership {fp.total_kg:7.1f} kg "
              f"({fp.embodied_pct:.0f}% embodied) | fleet of "
              f"{out['device_count']:3d} -> net reduction "
              f"{out['net_reduction_x_no_comm']:.1f}x")


if __name__ == "__main__":
    main()
