"""Quickstart: train a small foundation model with the carbon ledger on.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--arch opt-125m]

Trains a reduced OPT-style model on the synthetic token pipeline, records
per-step energy through the paper's component-level monitor, and prints
the resulting operational-carbon entry — the paper's §2.2 accounting run
on a real training loop.
"""

from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.core.carbon.accounting import CarbonLedger, EDGE_PUE
from repro.core.energy.devices import LAPTOP_M2PRO
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.train.trainer import TrainerConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the arch's full geometry (slow on CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced(num_layers=4, d_model=256, vocab_size=2048)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M")

    monitor = EnergyMonitor(ComponentModel.for_device(LAPTOP_M2PRO))
    res = train(cfg, TrainerConfig(steps=args.steps, batch=args.batch,
                                   seq_len=args.seq, log_every=20),
                monitor=monitor)

    ledger = CarbonLedger()
    ledger.add_operational_wh("quickstart-train", res.energy_wh,
                              pue=EDGE_PUE)
    print(f"\nfinal loss      : {res.final_loss:.4f}")
    print(f"throughput      : {res.steps_per_s:.2f} steps/s")
    print(f"modelled energy : {res.energy_wh:.4f} Wh "
          f"(component model: {LAPTOP_M2PRO.name})")
    print(f"operational CO2 : {ledger.operational_kg*1000:.4f} gCO2e "
          f"(grid {ledger.intensity_kg_per_kwh:.3f} kgCO2e/kWh)")


if __name__ == "__main__":
    main()
