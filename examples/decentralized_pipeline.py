"""End-to-end driver: DT-FM hybrid data+pipeline training on a simulated
edge mesh — the paper's §5 "distributed training methods for the edge",
executed for real with shard_map + ppermute.

    PYTHONPATH=src python examples/decentralized_pipeline.py \
        [--stages 4] [--data 2] [--steps 300] [--params-m 100]

Builds a (data x stage) mesh from CPU placeholder devices (each device =
one edge participant), splits an OPT-style decoder into pipeline stages,
and trains with GPipe microbatching.  Loss must decrease; the script also
prints the DT-FM analytic plan (step time, bubble, per-device energy) for
the same fleet so the executed schedule can be compared with the paper's
Table-2 model.

Default geometry is a ~14M-param model for a quick run; --params-m 100
trains a ~100M-param model for a few hundred steps (the deliverable's
end-to-end driver; allow ~30-60 min on CPU).
"""

import os

DATA = int(os.environ.get("EX_DATA", "2"))
STAGES = int(os.environ.get("EX_STAGES", "4"))
os.environ["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                           f"{DATA*STAGES} "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np   # noqa: E402

from repro import compat                            # noqa: E402
from repro.configs.opt import opt_config            # noqa: E402
from repro.core.energy.devices import LAPTOP_M2PRO  # noqa: E402
from repro.core.planner import dtfm                 # noqa: E402
from repro.data.pipeline import make_batch_fn       # noqa: E402
from repro.distributed.pipeline import (            # noqa: E402
    make_pipeline_loss, pipeline_train_step, stack_for_stages,
    unstack_stages)
from repro.optim import adamw                       # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--params-m", type=int, default=14,
                    help="~model size in millions (14 quick | 100 full)")
    args = ap.parse_args()

    import dataclasses
    base = opt_config("opt-125m")
    if args.params_m >= 100:
        # ~100M params: the full OPT-125m geometry with a smaller vocab
        cfg = dataclasses.replace(base, name="opt-100m-pipe",
                                  vocab_size=8192)
    else:
        cfg = dataclasses.replace(base, name="opt-14m-pipe",
                                  num_layers=8, d_model=384, num_heads=8,
                                  num_kv_heads=8, head_dim=48, d_ff=1536,
                                  vocab_size=4096)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.num_layers} layers")

    mesh = jax.make_mesh((DATA, STAGES), ("data", "stage"))
    print(f"mesh: {DATA} data x {STAGES} stages "
          f"({DATA*STAGES} simulated edge devices)")

    opt_cfg = adamw.OptConfig(learning_rate=3e-4, warmup_steps=20,
                              decay_steps=args.steps)
    init_fn, step_fn = pipeline_train_step(
        cfg, mesh, opt_cfg, num_microbatches=args.microbatches)

    with compat.set_mesh(mesh):
        rest, staged, opt = init_fn(jax.random.PRNGKey(0))
        data = make_batch_fn(cfg, args.batch, args.seq, seed=0)
        losses = []
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            rest, staged, opt, metrics = step_fn(rest, staged, opt, batch)
            losses.append(float(metrics["loss"]))
            if step % 20 == 0:
                print(f"step {step:4d}  loss {losses[-1]:.4f}")
        wall = time.time() - t0

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({args.steps} steps, {wall:.0f}s, "
          f"{args.steps/wall:.2f} steps/s)")
    assert last < first - 0.3, "pipeline training failed to learn"

    # analytic DT-FM plan for the equivalent edge fleet (paper Table 2 model)
    plan = dtfm.plan(cfg, [LAPTOP_M2PRO] * STAGES, batch=args.batch,
                     seq_len=args.seq, microbatches=args.microbatches,
                     data_parallel=DATA)
    print(f"\nDT-FM analytic plan ({STAGES} laptops x {DATA} pipelines):")
    print(f"  step time {plan.step_time_s:.2f}s  "
          f"bubble {plan.bubble_fraction:.2f}  "
          f"comm {plan.comm_s_per_step:.2f}s/step  "
          f"energy {plan.total_energy_wh_per_step*1000:.2f} mWh/step")

    # the same contract, heterogeneous: a smartphone joins, the placement
    # search hands it fewer layers, and the SAME executor runs that
    # non-uniform split (boundaries flow spec -> pipeline)
    from repro.core.energy.devices import SMARTPHONE_SD888   # noqa: E402
    from repro.core.placement import ordered_placement       # noqa: E402
    hetero = [LAPTOP_M2PRO] * (STAGES - 1) + [SMARTPHONE_SD888]
    spec = ordered_placement(cfg, hetero)
    print(f"\nheterogeneous placement (1 phone joins):\n{spec.describe()}")
    if len(spec.boundaries) - 1 == STAGES:
        loss_fn = make_pipeline_loss(cfg, mesh,
                                     num_microbatches=args.microbatches,
                                     boundaries=spec)
        from repro.models import params as PM                    # noqa: E402
        p = PM.init_params(cfg, jax.random.PRNGKey(1))
        st = stack_for_stages(cfg, p, spec)
        with compat.set_mesh(mesh):
            b = {k: jnp.asarray(v) for k, v in next(data).items()}
            nl = jax.jit(loss_fn)(p, st, b)
        print(f"  non-uniform split {spec.layer_counts} executes: "
              f"loss {float(nl):.4f}")


if __name__ == "__main__":
    main()
