"""DiLoCo-style low-communication training over a two-region edge fleet.

    PYTHONPATH=src python examples/diloco_edge.py [--rounds 8] [--inner 8]

Trains a reduced OPT-style model with the local-update trainer
(:mod:`repro.train.local_sgd`): each replica runs K inner AdamW steps,
then the fleet synchronizes pseudo-gradients with an int8-compressed
hierarchical allreduce whose wide-area cost is priced on the
:mod:`repro.core.net` topology — the full low-communication stack the
paper's edge setting needs, end to end on one host.
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_config
from repro.core.net import NetParams, Topology, sync_cost
from repro.core.sched.carbon_aware import FleetDevice
from repro.core.energy.devices import LAPTOP_M2PRO
from repro.optim import adamw
from repro.optim.compress import CompressConfig
from repro.train.local_sgd import LocalSGDConfig, train_local_sgd
from repro.train.trainer import TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-125m")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--inner", type=int, default=8, help="K inner steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(num_layers=4, d_model=256,
                                        vocab_size=2048)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M  "
          f"replicas={args.replicas}  K={args.inner}")

    fleet = [FleetDevice(spec=LAPTOP_M2PRO,
                         region=("europe", "north_america")[i % 2],
                         device_id=i) for i in range(args.replicas)]
    topo = Topology.from_fleet(fleet, params=NetParams(wan_bw_Bps=4e6))

    steps = args.rounds * args.inner
    ls = LocalSGDConfig(replicas=args.replicas, inner_steps=args.inner,
                        outer_lr=0.7, outer_momentum=0.9,
                        compress=CompressConfig(method="int8"))
    res = train_local_sgd(
        cfg, TrainerConfig(steps=steps, batch=args.batch,
                           seq_len=args.seq, log_every=args.inner),
        ls, adamw.OptConfig(learning_rate=3e-3, warmup_steps=5,
                            decay_steps=steps),
        topology=topo, sync_algorithm="hierarchical")

    # what the same fleet would pay syncing raw fp32 grads every step
    naive = sync_cost(topo, topo.devices, cfg.param_count(),
                      algorithm="ring", compress=None, dtype_bytes=4)

    print(f"\nfinal round loss     : {res.final_loss:.4f} "
          f"(first {res.round_losses[0]:.4f})")
    print(f"sync wire bytes/round: {res.sync_wire_bytes_per_round/1e6:.2f} MB"
          f" (int8)")
    print(f"modelled sync time   : {res.comm_time_s_per_round:.3f} s/round "
          f"-> {res.comm_time_s_per_step:.3f} s/step amortized")
    print(f"naive every-step sync: {naive.time_s:.3f} s/step "
          f"({naive.time_s / max(res.comm_time_s_per_step, 1e-12):.0f}x "
          f"more wide-area wire time)")


if __name__ == "__main__":
    main()
