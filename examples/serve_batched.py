"""Batched serving example: prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x7b]

Loads a reduced variant of any assigned architecture (``--arch`` accepts
all ten ids), prefILLS a batch of prompts, then decodes greedily — the
exact ``serve_step`` the decode dry-run shapes lower, including MoE
routing, SSM state caches (mamba2/jamba) and sliding-window caches
(mixtral).  Prints per-phase timing and the decode energy estimate from
the component model.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import flops as F
from repro.core.energy.devices import LAPTOP_M2PRO
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.models import model as M
from repro.models import params as P
from repro.serve.step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")      # reduced variant
    print(f"arch: {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}, {cfg.param_count()/1e6:.1f}M params)")

    params = P.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (args.batch, cfg.encoder_seq_len,
                                    cfg.d_model), jnp.float32)
        enc = M.encoder_forward(params, cfg, frames, {})

    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, max_new=args.max_new, enc=enc)
    out.block_until_ready()
    wall = time.time() - t0

    total = args.prompt_len + args.max_new
    monitor = EnergyMonitor(ComponentModel.for_device(LAPTOP_M2PRO))
    for i in range(args.max_new):
        monitor.record_step(
            flops=F.decode_flops(cfg, args.batch, args.prompt_len + i),
            hbm_bytes=F.decode_hbm_bytes(cfg, args.batch,
                                         args.prompt_len + i),
            duration_s=wall / total)

    print(f"generated {args.batch}x{args.max_new} tokens in {wall:.2f}s "
          f"({args.batch*args.max_new/wall:.1f} tok/s)")
    print(f"sample token ids: {list(map(int, out[0, -8:]))}")
    bd = monitor.breakdown_j()
    print(f"decode energy model ({LAPTOP_M2PRO.name}): "
          f"{monitor.total_j:.2f} J  "
          f"[compute {bd['compute']:.2f} | memory {bd['memory']:.2f} | "
          f"static {bd['static']:.2f}]")


if __name__ == "__main__":
    main()
