"""Batched serving example: continuous batching over a paged KV cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x7b]

Loads a reduced variant of any assigned architecture (``--arch`` accepts
all ten ids) and serves a mixed-length request set.  Architectures whose
decoder caches are token-paged (attn/mlp/moe decoders: llama3, qwen*,
granite, mixtral, opt) run through the continuous-batching engine —
admission on free KV blocks, prefill/decode interleaving, per-step
eviction, greedy + temperature/top-k sampling.  SSM / MLA /
encoder-decoder architectures (mamba2, jamba, deepseek-v3, whisper) fall
back to the dense ``greedy_generate`` path.  Both report per-token
energy/carbon from the component model.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import flops as F
from repro.core.energy.devices import LAPTOP_M2PRO
from repro.core.energy.monitor import ComponentModel, EnergyMonitor
from repro.models import model as M
from repro.models import params as P


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-smoke")      # reduced variant
    print(f"arch: {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}, {cfg.param_count()/1e6:.1f}M params)")
    params = P.init_params(cfg, jax.random.PRNGKey(0))

    if M.paged_decode_supported(cfg):
        run_engine(args, cfg, params)
    else:
        print(f"({args.arch} caches are not token-paged; dense greedy path)")
        run_dense(args, cfg, params)


def run_engine(args, cfg, params) -> None:
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    from repro.serve.paged_cache import blocks_for
    from repro.serve.sampling import SamplingParams

    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k)
    reqs = []
    for i in range(args.requests):
        L = 3 + (5 * i) % max(args.prompt_len - 2, 1)
        toks = jax.random.randint(jax.random.PRNGKey(10 + i), (L,), 0,
                                  cfg.vocab_size)
        reqs.append(Request(uid=f"req{i}", prompt=list(map(int, toks)),
                            max_new=args.max_new, sampling=sp))

    block = 8
    per_seq = blocks_for(args.prompt_len + args.max_new, block) + 1
    slots = min(args.requests, 4)
    ecfg = EngineConfig(max_slots=slots, block_size=block,
                        num_blocks=per_seq * slots + 2,
                        max_blocks_per_seq=per_seq)
    engine = ServeEngine(params, cfg, ecfg, device=LAPTOP_M2PRO)
    out = engine.run(reqs)
    s = engine.stats()

    print(f"served {len(out)} requests / "
          f"{int(s['tokens_generated'])} tokens in {engine.wall_s:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, {int(s['steps'])} engine steps, "
          f"{slots} slots)")
    print(f"paged KV: peak {s['peak_cache_bytes']/1e3:.1f} kB of "
          f"{s['pool_bytes']/1e3:.1f} kB pool; peak fragmentation "
          f"{s['frag_tokens_peak']:.0f} tokens, peak utilization "
          f"{100*s['utilization_peak']:.0f}%")
    print(f"energy ({LAPTOP_M2PRO.name}): {s['energy_j']:.2f} J "
          f"({s['j_per_token']:.3f} J/token, {s['carbon_g']:.4f} gCO2e)")
    first = out[reqs[0].uid]
    print(f"sample ({first.uid}): {first.tokens[:8]}")


def run_dense(args, cfg, params) -> None:
    from repro.serve.step import greedy_generate

    batch = args.requests
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, args.prompt_len), 0, cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (batch, cfg.encoder_seq_len,
                                    cfg.d_model), jnp.float32)
        enc = M.encoder_forward(params, cfg, frames, {})

    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, max_new=args.max_new, enc=enc)
    out.block_until_ready()
    wall = time.time() - t0

    total = args.prompt_len + args.max_new
    monitor = EnergyMonitor(ComponentModel.for_device(LAPTOP_M2PRO))
    for i in range(args.max_new):
        monitor.record_step(
            flops=F.decode_flops(cfg, batch, args.prompt_len + i),
            hbm_bytes=F.decode_hbm_bytes(cfg, batch, args.prompt_len + i),
            duration_s=wall / total)

    print(f"generated {batch}x{args.max_new} tokens in {wall:.2f}s "
          f"({batch*args.max_new/wall:.1f} tok/s)")
    print(f"sample token ids: {list(map(int, out[0, -8:]))}")
    bd = monitor.breakdown_j()
    print(f"decode energy model ({LAPTOP_M2PRO.name}): "
          f"{monitor.total_j:.2f} J  "
          f"[compute {bd['compute']:.2f} | memory {bd['memory']:.2f} | "
          f"static {bd['static']:.2f}]")


if __name__ == "__main__":
    main()
